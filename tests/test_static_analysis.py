"""Invariant linter + lock-order detector (src/repro/analysis/).

Three layers:

  * golden fixtures — a miniature tree per rule that MUST trip it (and a
    fixed twin that must not), so a rule can never silently stop firing;
  * the real tree — `run_lint` over the repo proper must be fully covered
    by the checked-in baseline, and the baseline must be exact (≤ 5
    entries, none stale) — the shrink-only contract;
  * `OrderedLock` — deterministic inversion detection, Condition
    integration, contention telemetry, and a hypothesis property test:
    schedules that respect a global order never trip the detector,
    schedules with a planted inversion always do.
"""

from __future__ import annotations

import subprocess
import sys
import textwrap
import threading
import time
from pathlib import Path

import pytest

from _hypothesis_compat import given, settings, st
from repro.analysis import locks
from repro.analysis.lint import (BaselineError, RULE_IDS, apply_baseline,
                                 load_baseline, run_lint)

REPO_ROOT = Path(__file__).resolve().parent.parent


# ==========================================================================
# fixture trees
# ==========================================================================

def _tree(tmp_path: Path, files: dict[str, str]) -> Path:
    for rel, body in files.items():
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(body))
    return tmp_path


def _rules_hit(tmp_path, files):
    return {f.rule for f in run_lint(_tree(tmp_path, files))}


def test_raw_clock_trips_and_perf_counter_passes(tmp_path):
    findings = run_lint(_tree(tmp_path, {
        "src/repro/serving/svc.py": """\
            import time
            from time import monotonic

            def bad():
                return time.time() + monotonic()

            def fine():
                return time.perf_counter()
            """,
    }))
    # both clock reads sit on line 5: the attribute call and the
    # from-import call each get their own finding
    assert [(f.rule, f.line) for f in findings] == \
        [("RAW-CLOCK", 5), ("RAW-CLOCK", 5)]
    messages = " ".join(f.message for f in findings)
    assert "time.time()" in messages and "monotonic" in messages
    assert "now" in findings[0].hint


def test_raw_clock_scope_and_pragma(tmp_path):
    findings = run_lint(_tree(tmp_path, {
        # out of scope: core/ code may read clocks
        "src/repro/core/clock_user.py": "import time\nx = time.time()\n",
        # pragma on the line above suppresses
        "src/repro/index/sweep.py": """\
            import time
            # lint: allow RAW-CLOCK
            t = time.time()
            """,
        "benchmarks/bench.py": "import time\nt0 = time.monotonic()\n",
    }))
    assert [(f.rule, f.path) for f in findings] == \
        [("RAW-CLOCK", "benchmarks/bench.py")]


def test_raw_store_trips_and_blobs_seam_passes(tmp_path):
    findings = run_lint(_tree(tmp_path, {
        "src/repro/serving/svc.py": """\
            def bad(store):
                return store.get("manifest")

            def fine(transport):
                transport.blobs.put("manifest", b"x")
                return transport.get_range(None)
            """,
    }))
    assert [(f.rule, f.line) for f in findings] == [("RAW-STORE", 2)]
    assert "transport" in findings[0].hint


def test_raw_store_benchmarks_may_seed_but_not_read(tmp_path):
    findings = run_lint(_tree(tmp_path, {
        "benchmarks/bench.py": """\
            def seed(store):
                store.put("blob", b"x" * 1024)   # fixture seeding: allowed

            def bad(store):
                return store.get("blob")
            """,
    }))
    assert [(f.rule, f.line) for f in findings] == [("RAW-STORE", 5)]


def test_bare_lock_trips_ordered_condition_passes(tmp_path):
    findings = run_lint(_tree(tmp_path, {
        "src/repro/storage/widget.py": """\
            import threading
            from threading import RLock

            a = threading.Lock()
            b = RLock()
            c = threading.Condition()
            d = threading.Condition(a)   # explicit lock: not a creation
            """,
        # locks.py itself is the sanctioned creation site
        "src/repro/analysis/locks.py": "import threading\n"
                                       "m = threading.Lock()\n",
    }))
    assert [(f.rule, f.line) for f in findings] == \
        [("BARE-LOCK", 4), ("BARE-LOCK", 5), ("BARE-LOCK", 6)]
    assert "OrderedLock" in findings[0].hint


def test_deprecated_ref_trips_outside_compat(tmp_path):
    findings = run_lint(_tree(tmp_path, {
        "src/repro/serving/svc.py": """\
            def f(s):
                return s.search_regex("a.b")
            """,
        "src/repro/compat.py": "def deprecated_call():\n    pass\n",
    }))
    assert [(f.rule, f.path) for f in findings] == \
        [("DEPRECATED-REF", "src/repro/serving/svc.py")]
    assert "search_regex" in findings[0].message


def test_kernel_parity_missing_ref_and_missing_test(tmp_path):
    base = {
        "src/repro/kernels/foo/ops.py": """\
            import jax.experimental.pallas as pl

            def op(x):
                return pl.pallas_call(None)(x)

            def helper(x):          # pure jnp: no twin required
                return x
            """,
        "src/repro/kernels/foo/ref.py": "",
    }
    findings = run_lint(_tree(tmp_path, base))
    assert [(f.rule, f.line) for f in findings] == [("KERNEL-PARITY", 3)]
    assert "op_ref" in findings[0].message

    # adding the ref but no test: still unpinned
    (tmp_path / "src/repro/kernels/foo/ref.py").write_text(
        "def op_ref(x):\n    return x\n")
    findings = run_lint(tmp_path)
    assert [f.rule for f in findings] == ["KERNEL-PARITY"]
    assert "never named in a test" in findings[0].message

    # ref + test mention: clean
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests/test_foo.py").write_text(
        "from repro.kernels.foo.ops import op\n")
    assert run_lint(tmp_path) == []


def test_swallowed_exc_trips_and_observable_handler_passes(tmp_path):
    findings = run_lint(_tree(tmp_path, {
        "src/repro/storage/io.py": """\
            def f():
                try:
                    g()
                except:
                    pass

            def g():
                try:
                    h()
                except Exception:
                    pass

            def h():
                try:
                    f()
                except Exception:
                    counter.inc()          # observable: fine
                try:
                    f()
                except ValueError:         # narrowed: fine
                    pass
            """,
    }))
    assert [(f.rule, f.line) for f in findings] == \
        [("SWALLOWED-EXC", 4), ("SWALLOWED-EXC", 10)]


def test_every_rule_has_a_tripping_fixture(tmp_path):
    """The union of the golden fixtures above covers all six rules."""
    hit = set()
    hit |= _rules_hit(tmp_path / "a", {
        "src/repro/serving/a.py": "import time\nt = time.time()\n"})
    hit |= _rules_hit(tmp_path / "b", {
        "src/repro/serving/b.py": "def f(store):\n    store.get('x')\n"})
    hit |= _rules_hit(tmp_path / "c", {
        "src/repro/index/c.py": "import threading\nl = threading.Lock()\n"})
    hit |= _rules_hit(tmp_path / "d", {
        "src/repro/index/d.py": "from repro.compat import deprecated_call\n"})
    hit |= _rules_hit(tmp_path / "e", {
        "src/repro/kernels/k/ops.py":
            "def op(x):\n    return pallas_call(x)\n",
        "src/repro/kernels/k/ref.py": ""})
    hit |= _rules_hit(tmp_path / "f", {
        "src/repro/storage/f.py":
            "try:\n    pass\nexcept Exception:\n    pass\n"})
    assert hit == set(RULE_IDS)
    assert len(RULE_IDS) == 6


# ==========================================================================
# baseline allowlist
# ==========================================================================

BASELINE_TEXT = """\
# comment
[[baseline]]
rule = "RAW-CLOCK"
path = "src/repro/serving/old.py"
reason = "legacy timer, tracked in ISSUE 9"
[[baseline]]
rule = "BARE-LOCK"
path = "src/repro/storage/old.py"   # trailing comment
reason = "migration pending"
"""


def test_baseline_round_trip(tmp_path):
    p = tmp_path / "baseline.toml"
    p.write_text(BASELINE_TEXT)
    entries = load_baseline(p)
    assert [(e.rule, e.path) for e in entries] == \
        [("RAW-CLOCK", "src/repro/serving/old.py"),
         ("BARE-LOCK", "src/repro/storage/old.py")]
    assert entries[0].reason == "legacy timer, tracked in ISSUE 9"


def test_baseline_rejects_missing_reason_and_garbage(tmp_path):
    p = tmp_path / "b.toml"
    p.write_text('[[baseline]]\nrule = "X"\npath = "y.py"\n')
    with pytest.raises(BaselineError):
        load_baseline(p)
    p.write_text('[[baseline]]\nrule = "X"\npath = "y.py"\nreason = ""\n')
    with pytest.raises(BaselineError):
        load_baseline(p)
    p.write_text("not toml at all\n")
    with pytest.raises(BaselineError):
        load_baseline(p)


def test_apply_baseline_splits_and_reports_stale(tmp_path):
    p = tmp_path / "baseline.toml"
    p.write_text(BASELINE_TEXT)
    entries = load_baseline(p)
    findings = run_lint(_tree(tmp_path, {
        "src/repro/serving/old.py": "import time\nt = time.time()\n",
        "src/repro/serving/new.py": "import time\nt = time.monotonic()\n",
    }))
    remaining, unused = apply_baseline(findings, entries)
    assert [f.path for f in remaining] == ["src/repro/serving/new.py"]
    # the BARE-LOCK entry matched nothing: stale, must be deleted
    assert [(e.rule, e.path) for e in unused] == \
        [("BARE-LOCK", "src/repro/storage/old.py")]


# ==========================================================================
# the real tree
# ==========================================================================

def test_real_tree_is_clean_and_baseline_exact():
    findings = run_lint(REPO_ROOT)
    baseline = load_baseline(
        REPO_ROOT / "src/repro/analysis/baseline.toml")
    assert len(baseline) <= 5, "the baseline grows never — fix, don't add"
    remaining, unused = apply_baseline(findings, baseline)
    assert remaining == [], "un-baselined violations:\n" + \
        "\n".join(f.render() for f in remaining)
    assert unused == [], "stale baseline entries (delete them): " + \
        str([(e.rule, e.path) for e in unused])


def test_cli_strict_exits_zero():
    proc = subprocess.run(
        [sys.executable, str(REPO_ROOT / "scripts/lint_invariants.py"),
         "--strict"], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


# ==========================================================================
# OrderedLock: the lock-order detector
# ==========================================================================

@pytest.fixture()
def armed_detector():
    was = locks.armed()
    locks.arm(True)
    locks.reset()
    yield
    locks.reset()
    locks.arm(was)
    locks.bind_telemetry(None)


def test_two_lock_inversion_detected(armed_detector):
    a, b = locks.OrderedLock("t2.a"), locks.OrderedLock("t2.b")
    with a:
        with b:
            pass
    with pytest.raises(locks.LockOrderViolation) as exc:
        with b:
            with a:
                pass
    assert exc.value.cycle[0] == exc.value.cycle[-1] == "t2.a"
    assert set(exc.value.cycle) == {"t2.a", "t2.b"}


def test_three_lock_cycle_detected(armed_detector):
    a = locks.OrderedLock("t3.a")
    b = locks.OrderedLock("t3.b")
    c = locks.OrderedLock("t3.c")
    with a, b:
        pass
    with b, c:
        pass
    with pytest.raises(locks.LockOrderViolation) as exc:
        with c, a:
            pass
    assert set(exc.value.cycle) == {"t3.a", "t3.b", "t3.c"}


def test_violating_edge_not_committed(armed_detector):
    """A caught violation must not poison later order-respecting use."""
    a, b = locks.OrderedLock("tnc.a"), locks.OrderedLock("tnc.b")
    with a, b:
        pass
    with pytest.raises(locks.LockOrderViolation):
        with b:
            with a:
                pass
    # the b->a edge was rejected, so a->b remains legal
    with a, b:
        pass


def test_order_respecting_nesting_never_trips(armed_detector):
    a, b, c = (locks.OrderedLock(f"ok.{n}") for n in "abc")
    for _ in range(3):
        with a, b, c:
            pass
        with a, c:
            pass
        with b, c:
            pass
    edges = locks.order_edges()
    assert "ok.b" in edges["ok.a"] and "ok.c" in edges["ok.b"]


def test_self_deadlock_reported_not_hung(armed_detector):
    lock = locks.OrderedLock("self.lock")
    with lock:
        with pytest.raises(locks.LockOrderViolation, match="self-deadlock"):
            lock.acquire()


def test_reentrant_lock_reenters(armed_detector):
    lock = locks.OrderedLock("re.lock", reentrant=True)
    with lock:
        with lock:
            assert lock._is_owned()
    assert not lock.locked()


def test_disarmed_is_passthrough():
    was = locks.armed()
    locks.arm(False)
    try:
        locks.reset()
        a, b = locks.OrderedLock("off.a"), locks.OrderedLock("off.b")
        with a, b:
            pass
        with b, a:        # inversion, but detection is off
            pass
        assert locks.order_edges() == {}
    finally:
        locks.arm(was)
        locks.reset()


def test_condition_integration(armed_detector):
    cond = locks.ordered_condition("cond.test")
    box = []

    def consumer():
        with cond:
            while not box:
                cond.wait(timeout=5.0)
            box.append("seen")

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.02)
    with cond:
        box.append("item")
        cond.notify()
    t.join(timeout=5.0)
    assert not t.is_alive() and box == ["item", "seen"]


def test_contention_telemetry(armed_detector):
    from repro.serving.telemetry import Telemetry
    registry = Telemetry()
    locks.bind_telemetry(registry)
    hot = locks.OrderedLock("hot.lock")

    def holder():
        with hot:
            time.sleep(0.05)

    t = threading.Thread(target=holder)
    t.start()
    time.sleep(0.01)
    with hot:
        pass
    t.join()
    snap = registry.snapshot()
    assert snap["lock.hot.lock.contentions"] == 1
    assert snap["lock.hot.lock.wait_s"]["count"] == 1
    assert hot.contentions == 1 and hot.wait_s > 0
    agg = locks.contention_summary()["hot.lock"]
    assert agg["contentions"] == 1


def test_telemetry_internal_locks_never_bind(armed_detector):
    """Binding must not recurse: the registry's own locks are exempt."""
    from repro.serving.telemetry import Telemetry
    registry = Telemetry()
    locks.bind_telemetry(registry)
    counter = registry.counter("some.metric")   # creates telemetry.* locks
    counter.inc()
    assert not any(name.startswith("lock.telemetry.")
                   for name in registry.snapshot())


# ==========================================================================
# property test: planted inversions are always caught, order-respecting
# schedules never are
# ==========================================================================

def _run_schedule(lock_objs, schedule):
    """Acquire each sequence nested-in-order on the calling thread."""
    for seq in schedule:
        acquired = []
        try:
            for idx in seq:
                lock_objs[idx].acquire()
                acquired.append(lock_objs[idx])
        finally:
            for obj in reversed(acquired):
                obj.release()


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_lock_order_property(data):
    n = data.draw(st.integers(min_value=2, max_value=6))
    n_seqs = data.draw(st.integers(min_value=1, max_value=5))
    plant = data.draw(st.integers(min_value=0, max_value=1))

    was = locks.armed()
    locks.arm(True)
    locks.reset()
    try:
        objs = [locks.OrderedLock(f"prop.{i}") for i in range(n)]
        # order-respecting schedules: every sequence is an ascending
        # sample of the global order 0 < 1 < ... < n-1
        schedule = []
        for _ in range(n_seqs):
            picks = sorted({
                data.draw(st.integers(min_value=0, max_value=n - 1))
                for _ in range(data.draw(
                    st.integers(min_value=1, max_value=n)))})
            schedule.append(picks)
        _run_schedule(objs, schedule)   # must never raise

        if plant:
            lo = data.draw(st.integers(min_value=0, max_value=n - 2))
            hi = data.draw(st.integers(min_value=lo + 1, max_value=n - 1))
            # force the forward edge, then invert it
            _run_schedule(objs, [[lo, hi]])
            with pytest.raises(locks.LockOrderViolation):
                _run_schedule(objs, [[hi, lo]])
    finally:
        locks.reset()
        locks.arm(was)
