"""`hypothesis` when available, a tiny seeded-example fallback otherwise.

The property tests in this suite only use a small slice of the hypothesis
API: `@given` over `st.integers`, `st.floats`, `st.lists`, and `st.data()`
draws, with `@settings(max_examples=..., deadline=...)` on top. When the
real package is installed (see requirements-dev.txt) we re-export it and
get full shrinking/coverage. Offline images without it still run every
property test against a deterministic batch of seeded random examples —
weaker than hypothesis, but far better than skipping the module.

Usage in tests:

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import random
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        """A strategy is just a callable drawing one value from an RNG."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _DataObject:
        """Mimics the object produced by `st.data()`."""

        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy: _Strategy):
            return strategy.example(self._rng)

    class _Namespace:
        @staticmethod
        def integers(min_value=0, max_value=2**63 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements: _Strategy, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size if max_size is not None
                                else min_size + 10)
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def data():
            return _Strategy(_DataObject)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

    st = _Namespace()

    def given(*strategies: _Strategy):
        def decorate(fn):
            # NOTE: the wrapper must expose a ZERO-arg signature — pytest
            # would otherwise read the wrapped test's parameters as fixture
            # requests (functools.wraps copies __wrapped__, which
            # inspect.signature follows, so it cannot be used here).
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for ex in range(n):
                    rng = random.Random(
                        (zlib.crc32(fn.__qualname__.encode()) << 32) | ex)
                    fn(*(s.example(rng) for s in strategies))
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = _DEFAULT_EXAMPLES
            return wrapper
        return decorate

    def settings(max_examples: int | None = None, **_kw):
        def decorate(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return decorate


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
