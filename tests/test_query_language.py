"""Composable query language + logical/physical planner.

Three layers of guarantees:

  1. the LANGUAGE: normalization rewrites are semantics-preserving and
     canonical, and `parse(to_string(q)) == normalize(q)` round-trips;
  2. the PLANNER: every executable tree returns EXACTLY the documents a
     brute-force corpus scan returns (the scan is an independent
     re-implementation, not the planner's own verifier), on several
     seeded corpora, monolithic and segmented, sorted and bitmap;
  3. the KERNEL: the batched AND/OR/ANDNOT program evaluator matches
     its jnp reference and a Python-set oracle.
"""

import re

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import make_logs_like, write_corpus
from repro.data.tokenizer import parse_words
from repro.index import (And, Builder, BuilderConfig, Index, Not, Or,
                         Phrase, PureNegationError, Query, QuerySyntaxError,
                         Regex, Searcher, Term, normalize, parse,
                         physical_plan, query_words, to_string)
from repro.index.builder import NGRAM_PREFIX
from repro.index.planner import make_job, plan_batch
from repro.kernels.intersect import (OP_AND, OP_ANDNOT, OP_OR,
                                     bitmap_to_docs, combine_batch,
                                     pack_programs, postings_to_bitmap_batch)
from repro.serving import SearchService
from repro.storage import InMemoryBlobStore, SimCloudStore, SimCloudTransport


# ===================================================================== AST
def test_operator_sugar():
    a, b = Term("a"), Term("b")
    assert a & b == And((a, b))
    assert a | b == Or((a, b))
    assert ~a == Not(a)
    assert ~(a & b) == Not(And((a, b)))


def test_normalize_flatten_and_dedupe():
    a, b, c = Term("a"), Term("b"), Term("c")
    assert normalize(And((a, And((b, c))))) == And((a, b, c))
    assert normalize(Or((Or((a, b)), c))) == Or((a, b, c))
    assert normalize(And((a, a))) == a                 # dedupe + collapse
    assert normalize(And((a, b, a))) == And((a, b))    # stable order
    assert normalize(And((a,))) == a


def test_normalize_negation_rewrites():
    a, b = Term("a"), Term("b")
    assert normalize(Not(Not(a))) == a
    assert normalize(Not(And((a, b)))) == Or((Not(a), Not(b)))
    assert normalize(Not(Or((a, b)))) == And((Not(a), Not(b)))
    # De Morgan output flattens into an enclosing And
    q = And((Term("c"), Not(Or((a, b)))))
    assert normalize(q) == And((Term("c"), Not(a), Not(b)))
    # idempotent
    for tree in (q, Not(Not(Not(a))), Or((a, Not(And((a, b)))))):
        assert normalize(normalize(tree)) == normalize(tree)


def test_normalize_phrase():
    assert normalize(Phrase(("x",))) == Term("x")
    assert normalize(Phrase(("x", "y"), slop=2)) == Phrase(("x", "y"), 2)
    with pytest.raises(ValueError):
        normalize(Phrase(()))


def test_query_words_typeerror_and_regex_dedupe():
    with pytest.raises(TypeError):
        query_words(And((Term("a"), "oops")))        # type: ignore[arg-type]
    with pytest.raises(TypeError):
        normalize(Or((Term("a"), 3)))                # type: ignore[arg-type]
    # overlapping n-gram expansions dedupe across Regex nodes
    q = And((Regex("abcd"), Regex("bcde"), Term("abc")))
    ws = query_words(q)
    assert ws == [NGRAM_PREFIX + g for g in ("abc", "bcd", "cde")] + ["abc"]
    assert len(ws) == len(set(ws))
    # Not and Phrase contribute their words
    assert query_words(And((Phrase(("p", "q")), Not(Term("n"))))) == \
        ["p", "q", "n"]


# ================================================================== parsing
def test_parse_grammar():
    a, b, c = Term("a"), Term("b"), Term("c")
    assert parse("hello") == Term("hello")
    assert parse("a b") == And((a, b))
    assert parse("a AND b") == And((a, b))
    assert parse("a and b") == And((a, b))           # case-insensitive
    assert parse("a OR b c") == Or((a, And((b, c))))  # AND binds tighter
    assert parse("(a OR b) c") == And((Or((a, b)), c))
    assert parse("a NOT b") == And((a, Not(b)))
    assert parse("a -b") == And((a, Not(b)))
    assert parse("a NOT (b OR c)") == And((a, Not(b), Not(c)))  # De Morgan
    assert parse('"disk full"') == Phrase(("disk", "full"))
    assert parse('"disk full"~3') == Phrase(("disk", "full"), slop=3)
    assert parse('"one"') == Term("one")             # 1-word phrase = term
    assert parse("re:/blk_[0-9]+/") == Regex("blk_[0-9]+")
    assert parse(r"re:/a\/b/") == Regex("a/b")       # escaped slash
    assert parse("x re:/err/ y") == And((Term("x"), Regex("err"), Term("y")))


def test_parse_uses_document_tokenizer():
    # same analyzer as the Builder: lowercased, punctuation splits words
    assert parse("Node-7,x") == And((Term("node-7"), Term("x")))
    assert parse("ERROR") == Term("error")
    assert parse('"Disk FULL!"') == Phrase(("disk", "full"))


def test_parse_errors():
    for bad in ("", "   ", "(a", "a)", '"unterminated', "re:/open",
                "a OR", "AND"):
        with pytest.raises(QuerySyntaxError):
            parse(bad)


_WORDS = ["alpha", "bravo", "cat-5", "d.e", "under_score", "n0de", "xyz"]


def _random_tree(rng, depth=0) -> Query:
    roll = rng.random()
    if depth >= 3 or roll < 0.35:
        return Term(_WORDS[rng.randrange(len(_WORDS))])
    if roll < 0.45:
        n = rng.randrange(2, 4)
        return Phrase(tuple(_WORDS[rng.randrange(len(_WORDS))]
                            for _ in range(n)),
                      slop=rng.randrange(0, 3))
    if roll < 0.55:
        return Regex("blk_[0-9]+" if rng.random() < 0.5 else "shuffle_7")
    if roll < 0.65:
        return Not(_random_tree(rng, depth + 1))
    kind = And if rng.random() < 0.5 else Or
    n = rng.randrange(2, 4)
    return kind(tuple(_random_tree(rng, depth + 1) for _ in range(n)))


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**32))
def test_round_trip_property(seed):
    import random
    rng = random.Random(seed)
    q = _random_tree(rng)
    assert parse(to_string(q)) == normalize(q)
    # and printing the normalized form is a fixed point
    assert parse(to_string(normalize(q))) == normalize(q)


def test_round_trip_quotes_keyword_terms():
    q = Term("and")
    assert parse(to_string(q)) == q


def test_phrase_routes_through_analyzer():
    # directly-constructed phrases analyze like parse() and the Builder
    assert Phrase(("Failed", "fetch")) == Phrase(("failed", "fetch"))
    assert Phrase(("disk full!",)) == Phrase(("disk", "full"))
    assert normalize(Phrase(("one!",))) == Term("one")


def test_regex_backslash_round_trip():
    for pat in (r"a\d+", "a/b", r"a\/b", "trailing\\", r"\\literal"):
        q = Regex(pat)
        assert parse(to_string(q)) == q, pat


def test_to_string_rejects_unanalyzable_terms():
    # such terms could never match an indexed document; printing them
    # would produce unparseable or lossy text
    for w in ("!!!", "Error", "a b"):
        with pytest.raises(ValueError):
            to_string(Term(w))


# ================================================================== planner
def test_pure_negation_rejected():
    a, b = Term("a"), Term("b")
    for bad in (Not(a), Or((a, Not(b))), And((Not(a), Not(b))),
                Not(And((a, b)))):
        with pytest.raises(PureNegationError):
            physical_plan(normalize(bad))
        with pytest.raises(PureNegationError):
            make_job(bad)
    # parse-level spellings reject too
    for text in ("NOT a", "-a", "a OR NOT b", "NOT (a b)"):
        with pytest.raises(PureNegationError):
            make_job(parse(text))


def test_gramless_regex():
    # alone: un-prefilterable, rejected (paper §IV-F policy)
    with pytest.raises(ValueError):
        make_job(Regex("[0-9]+"))
    # under And with a positive sibling: rides the sibling's candidates
    job = make_job(And((Term("a"), Regex("[0-9]+"))))
    assert job.plan is not None
    assert job.plan.lookup_words == ["a"]


def test_gramful_regex_on_gramless_index_raises_typed():
    """ROADMAP known-wart regression: a regex with literal n-gram runs
    against an index built WITHOUT index_ngrams used to silently return
    misses (the never-inserted gram terms hash to unrelated bins and
    intersect to nothing); the planner now raises a typed error."""
    from repro.index import GramlessIndexError

    store = InMemoryBlobStore()
    docs = make_logs_like(150, seed=4)
    corpus = write_corpus(store, "corpus/gl", docs, n_blobs=1)
    plain = Index.build(corpus, BuilderConfig(B=900, F0=1.0), store,
                        "index/gl")
    searcher = plain.searcher()
    for call in (lambda: searcher.query(Regex(r"blk_1[0-9]2")),
                 lambda: searcher.query_batch([Regex(r"blk_1[0-9]2")]),
                 lambda: searcher.query(
                     And((Term("error"), Regex(r"blk_1[0-9]2")))),
                 lambda: searcher.regex_query(r"blk_1[0-9]2")):
        with pytest.raises(GramlessIndexError, match="index_ngrams"):
            call()
    # a mismatched gram size is the same silent miss — also typed
    grammed = Index.build(corpus, BuilderConfig(B=900, F0=1.0,
                                                index_ngrams=4),
                          store, "index/gl4")
    with pytest.raises(GramlessIndexError, match="ngram=4"):
        grammed.searcher().query(Regex(r"blk_1[0-9]2", ngram=3))
    # the matching size works, and gramless-pattern rejection is intact
    res = grammed.searcher().query(Regex(r"blk_1[0-9]2", ngram=4))
    assert all("blk_1" in t for t in res.texts)
    with pytest.raises(ValueError):
        grammed.searcher().query(Regex("[0-9]+", ngram=4))


def test_lookup_set_skips_unbounded_or_branch():
    # Or(b, NOT c) bounds nothing — its words need no superpost fetches
    q = And((Term("a"), Or((Term("b"), Not(Term("c"))))))
    plan = physical_plan(normalize(q))
    assert plan.lookup_words == ["a"]


def test_classic_shapes_compile_to_classic_jobs():
    for q in (Term("a"), And((Term("a"), Term("b"))),
              Or((And((Term("a"), Term("b"))), Term("c")))):
        job = make_job(q)
        assert job.plan is None and job.accept_words is not None
    rjob = make_job(Regex("blk_[0-9]+"))
    assert rjob.plan is None and rjob.accept_text is not None
    njob = make_job(And((Term("a"), Not(Term("b")))))
    assert njob.plan is not None and njob.accept_doc is not None


# ------------------------------------------------- the brute-force oracle
def _scan(q: Query, text: str, tokens: list[str]) -> bool:
    """Independent re-implementation of query semantics for the oracle."""
    if isinstance(q, Term):
        return q.word in tokens
    if isinstance(q, And):
        return all(_scan(s, text, tokens) for s in q.items)
    if isinstance(q, Or):
        return any(_scan(s, text, tokens) for s in q.items)
    if isinstance(q, Not):
        return not _scan(q.item, text, tokens)
    if isinstance(q, Regex):
        return re.search(q.pattern, text) is not None
    assert isinstance(q, Phrase)
    k = len(q.words)
    for s in range(len(tokens)):
        if tokens[s] != q.words[0]:
            continue
        i = s
        good = True
        for w in q.words[1:]:
            nxt = [j for j in range(i + 1, len(tokens)) if tokens[j] == w]
            if not nxt:
                good = False
                break
            i = nxt[0]
        if good and (i - s + 1) - k <= q.slop:
            return True
    return False


def _oracle(q: Query, docs: list[str]) -> set[str]:
    return {d for d in docs if _scan(q, d, parse_words(d))}


def _mixed_queries(docs: list[str]) -> list[Query]:
    """Composable shapes over words that actually occur in the corpus."""
    toks = parse_words(docs[0])
    w0, w1 = toks[0], toks[1]
    return [
        And((Term("info"), Not(Term("block")))),          # NOT common word
        And((Term("error"), Not(Term("starting")))),
        And((Term("error"), Not(Phrase((w0, w1))))),      # NOT phrase
        Phrase((w0, w1)),
        Phrase(("received", "block"), slop=2),
        And((Term("info"), Phrase((w0, w1)))),
        Or((Phrase(("received", "block")), Term("error"))),
        And((Term("info"), Regex(r"blk_4[0-9]+"))),       # Regex under And
        And((Regex(r"blk_[0-9]+"), Not(Term("info")))),
        Or((And((Term("info"), Not(Term("from")))), Term("error"))),
        And((Term("info"), Or((Term("block"), Not(Term("error")))))),
        parse("info NOT block starting"),
        parse('"received block"~1 OR error'),
        parse("info -(from OR block)"),
    ]


@pytest.mark.parametrize("seed,n_docs,B", [(11, 1200, 1000),
                                           (29, 1500, 1600),
                                           (47, 900, 1400)])
def test_planner_exact_vs_corpus_scan(seed, n_docs, B):
    """Acceptance: every composable query returns exactly the brute-force
    scan's documents, on several seeded corpora."""
    store = InMemoryBlobStore()
    docs = make_logs_like(n_docs, seed=seed)
    corpus = write_corpus(store, "corpus/ql", docs, n_blobs=3)
    Builder(BuilderConfig(B=B, F0=1.0, index_ngrams=3)).build(
        corpus, store, "index/ql")
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)), "index/ql")
    queries = _mixed_queries(docs)
    # serial, batched-sorted, and batched-bitmap all agree with the scan
    batched = s.query_batch(queries)
    bitmap = s.query_batch(queries, impl="bitmap")
    for q, rb, rbm in zip(queries, batched, bitmap):
        expect = _oracle(normalize(q), docs)
        assert set(rb.texts) == expect, to_string(q)
        assert rb.texts == rbm.texts and rb.refs == rbm.refs, to_string(q)
        single = s.query(q)
        assert single.texts == rb.texts and single.refs == rb.refs


def test_planner_exact_through_service_and_topk():
    store = InMemoryBlobStore()
    docs = make_logs_like(1000, seed=3)
    corpus = write_corpus(store, "corpus/qs", docs, n_blobs=2)
    Builder(BuilderConfig(B=1800, F0=1.0, index_ngrams=3)).build(
        corpus, store, "index/qs")
    svc = SearchService(SimCloudTransport(SimCloudStore(store, seed=2)),
                        "index/qs", cache_size=8)
    q = parse("info NOT block")
    expect = _oracle(q, docs)
    assert set(svc.search(q).texts) == expect
    assert set(svc.search("info NOT block").texts) == expect   # text form
    got = svc.search_batch([q, "error", parse('"received block" OR error')])
    assert set(got[0].texts) == expect
    # top-K returns verified matches only, k of them when available
    k = min(3, len(expect))
    topk = svc.search(q, top_k=3)
    assert len(topk.texts) == k and set(topk.texts) <= expect


def test_service_cache_keys_normalize():
    store = InMemoryBlobStore()
    docs = make_logs_like(400, seed=8)
    corpus = write_corpus(store, "corpus/qn", docs, n_blobs=2)
    Builder(BuilderConfig(B=600, F0=1.0)).build(corpus, store, "index/qn")
    svc = SearchService(SimCloudTransport(SimCloudStore(store, seed=2)),
                        "index/qn", cache_size=8)
    a, b, c = Term("info"), Term("block"), Term("from")
    svc.search(And((a, And((b, c)))))
    assert svc.cache_hits == 0
    svc.search(And((a, b, c)))                   # equivalent spelling
    assert svc.cache_hits == 1
    svc.search(parse("info block from"))         # parsed spelling
    assert svc.cache_hits == 2


def test_phrase_order_and_slop_semantics():
    store = InMemoryBlobStore()
    docs = ["alpha beta gamma", "beta alpha gamma", "alpha x beta",
            "alpha x y beta", "beta gamma alpha beta x", "gamma delta"]
    corpus = write_corpus(store, "corpus/ph", docs, n_blobs=1)
    Builder(BuilderConfig(B=256, F0=0.5)).build(corpus, store, "index/ph")
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=1)), "index/ph")

    def texts(q):
        return set(s.query(q).texts)

    assert texts(Phrase(("alpha", "beta"))) == {docs[0], docs[4]}
    assert texts(Phrase(("alpha", "beta"), slop=1)) == \
        {docs[0], docs[2], docs[4]}
    assert texts(Phrase(("alpha", "beta"), slop=2)) == \
        {docs[0], docs[2], docs[3], docs[4]}
    assert texts(Phrase(("beta", "gamma"))) == {docs[0], docs[4]}
    assert texts(And((Term("gamma"), Not(Phrase(("alpha", "beta")))))) == \
        {docs[1], docs[5]}
    for q, expect in [
            (Phrase(("alpha", "beta")), {docs[0], docs[4]}),
            (Phrase(("alpha", "beta"), slop=1), {docs[0], docs[2], docs[4]}),
    ]:
        assert _oracle(q, docs) == expect        # oracle agrees with itself


def test_segmented_matches_monolithic_for_new_shapes():
    """Base + delta segments answer composable queries exactly like a
    monolithic rebuild of the concatenated corpus."""
    store = InMemoryBlobStore()
    base_docs = make_logs_like(700, seed=21)
    delta_docs = make_logs_like(300, seed=22)
    all_docs = base_docs + delta_docs
    cfg = BuilderConfig(B=1800, F0=1.0, index_ngrams=3)

    base_corpus = write_corpus(store, "corpus/sg-base", base_docs, n_blobs=2)
    index = Index.build(base_corpus, cfg,
                        SimCloudTransport(SimCloudStore(store, seed=4)),
                        "index/sg")
    w = index.writer()
    w.append(write_corpus(store, "corpus/sg-delta", delta_docs, n_blobs=1))
    w.commit()
    seg = index.searcher()
    assert seg.n_units == 2

    mono_store = InMemoryBlobStore()
    mono_corpus = write_corpus(mono_store, "corpus/sg-all", all_docs,
                               n_blobs=3)
    Builder(cfg).build(mono_corpus, mono_store, "index/sg-all")
    mono = Searcher(SimCloudTransport(SimCloudStore(mono_store, seed=4)),
                    "index/sg-all")

    queries = _mixed_queries(all_docs)
    seg_res = seg.query_batch(queries)
    mono_res = mono.query_batch(queries)
    for q, a, b in zip(queries, seg_res, mono_res):
        expect = _oracle(normalize(q), all_docs)
        assert set(a.texts) == expect, to_string(q)
        assert set(b.texts) == expect, to_string(q)
        assert sorted(a.texts) == sorted(b.texts)


def test_common_word_negation_prunes_candidates():
    store = InMemoryBlobStore()
    docs = make_logs_like(1500, seed=11)
    corpus = write_corpus(store, "corpus/cn", docs, n_blobs=2)
    report = Builder(BuilderConfig(B=1200, F0=1.0)).build(
        corpus, store, "index/cn")
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)),
                 "index/cn")
    common_w = "block"
    assert common_w in report.common_words
    q = And((Term("info"), Not(Term(common_w))))
    plan = physical_plan(normalize(q), units=(s,))
    assert plan.subtract_words == frozenset({common_w})
    assert plan.lookup_words == ["info", common_w]
    pruned = s.query(q)
    plain = s.query(Term("info"))
    assert set(pruned.texts) == _oracle(normalize(q), docs)
    # the exact ANDNOT prune removed the negated docs BEFORE the doc round
    assert pruned.stats.n_candidates < plain.stats.n_candidates
    assert pruned.stats.n_false_positives == 0
    # hashed (non-common) negation must NOT subtract — unsound
    q2 = And((Term("info"), Not(Term("node42"))))
    plan2 = physical_plan(normalize(q2), units=(s,))
    assert plan2.subtract_words == frozenset()
    assert plan2.lookup_words == ["info"]
    assert set(s.query(q2).texts) == _oracle(normalize(q2), docs)


def test_plan_batch_mixed_with_classic_byte_path():
    """A batch mixing classic and planned shapes: classic members keep
    plan=None (the byte-identical path) and all members stay exact."""
    store = InMemoryBlobStore()
    docs = make_logs_like(800, seed=17)
    corpus = write_corpus(store, "corpus/mx", docs, n_blobs=2)
    Builder(BuilderConfig(B=1500, F0=1.0, index_ngrams=3)).build(
        corpus, store, "index/mx")
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=9)), "index/mx")
    queries = [Term("error"), And((Term("info"), Term("block"))),
               Regex(r"blk_1[0-9]+"),
               And((Term("info"), Not(Term("block")))),
               Phrase(("received", "block"))]
    jobs = plan_batch(queries, units=(s,))
    assert [j.plan is None for j in jobs] == [True, True, True, False, False]
    for q, r in zip(queries, s.query_batch(queries)):
        assert set(r.texts) == _oracle(normalize(q), docs), to_string(q)


# =================================================================== kernel
def _set_eval(posts, steps):
    slots = [set(p.tolist()) for p in posts]
    for op, a, b in steps:
        if op == OP_AND:
            slots.append(slots[a] & slots[b])
        elif op == OP_OR:
            slots.append(slots[a] | slots[b])
        else:
            slots.append(slots[a] - slots[b])
    return slots[-1]


def _random_program(rng, n_leaves):
    steps = []
    n_slots = n_leaves
    for s in range(rng.integers(1, 6)):
        op = int(rng.integers(0, 3))
        a = int(rng.integers(0, n_slots))
        b = int(rng.integers(0, n_slots))
        steps.append((op, a, b))
        n_slots += 1
    # final step must consume the running frontier to be a sane program;
    # for oracle purposes any DAG is fine — result is the last slot
    return steps


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**16))
def test_combine_batch_matches_ref_and_sets(seed):
    rng = np.random.default_rng(seed)
    n_docs = int(rng.integers(40, 3000))
    Q = int(rng.integers(1, 6))
    batch, programs = [], []
    for _ in range(Q):
        L = int(rng.integers(1, 5))
        posts = [np.unique(rng.integers(0, n_docs,
                                        int(rng.integers(1, n_docs))))
                 .astype(np.uint32) for _ in range(L)]
        batch.append(posts)
        programs.append(_random_program(rng, L))
    L_max = max(len(p) for p in batch)
    W = (n_docs + 31) // 32
    bitmaps = np.zeros((Q, L_max, W), dtype=np.uint32)
    for q, posts in enumerate(batch):
        bitmaps[q, :len(posts)] = postings_to_bitmap_batch(
            [posts], n_docs)[0]
    padded = [[(op, a + (L_max - len(batch[q]) if a >= len(batch[q]) else 0),
                b + (L_max - len(batch[q]) if b >= len(batch[q]) else 0))
               for op, a, b in prog]
              for q, prog in enumerate(programs)]
    progs = pack_programs(padded, L_max)
    out_p, cnt_p = combine_batch(bitmaps, progs, impl="pallas")
    out_r, cnt_r = combine_batch(bitmaps, progs, impl="ref")
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(cnt_p), np.asarray(cnt_r))
    for q in range(Q):
        expect = np.array(sorted(_set_eval(batch[q], programs[q])),
                          dtype=np.uint32)
        got = bitmap_to_docs(np.asarray(out_p)[q])
        np.testing.assert_array_equal(got, expect)
        assert int(cnt_p[q]) == len(expect)


def test_pack_programs_pads_with_identity():
    progs = pack_programs([[(OP_AND, 0, 1)],
                           [(OP_OR, 0, 1), (OP_ANDNOT, 2, 0)]],
                          n_layers=2)
    assert progs.shape == (2, 2, 3)
    # the padded step re-ANDs the previous result with itself
    assert tuple(progs[0, 1]) == (OP_AND, 2, 2)
