"""Index lifecycle: build/open façade, segmented writer, manifest
generations, generation-keyed caches, and the StorageTransport protocol.

The load-bearing acceptance criterion: byte-identity across the
redesign. `query_batch` through `Index.open(...).searcher()` over a
base+segments index must equal a monolithic rebuild of the concatenated
corpus, and the legacy `Searcher(cloud, prefix)` constructor must raise
a typed `DeprecatedAPIError` by default while `REPRO_ALLOW_DEPRECATED=1`
restores the old warn-and-work shim with identical results."""

import threading

import numpy as np
import pytest

from repro.compat import DeprecatedAPIError
from repro.data import make_logs_like, write_corpus
from repro.data.corpus import Corpus
from repro.data.tokenizer import distinct_words
from repro.index import (And, BuilderConfig, Index, MultiSegmentSearcher,
                         Or, Regex, Searcher, Term)
from repro.index.lifecycle import decode_manifest, encode_manifest
from repro.serving import SearchService
from repro.storage import (BlobStoreTransport, InMemoryBlobStore,
                           RangeRequest, SimCloudStore, SimCloudTransport,
                           SuperpostCache, TransportError, TransportPolicy,
                           as_transport)

# index_ngrams: the MIXED workload includes a Regex, and the planner now
# rejects gramful regexes against gramless indexes (GramlessIndexError)
# instead of silently missing — so the fixture must actually index grams
CFG = BuilderConfig(B=1200, F0=1.0, hedge_layers=1, index_ngrams=3)

MIXED = [
    "error", "info", "block",
    And((Term("error"), Term("block"))),
    Or((Term("warn"), Term("node7"))),
    Or((And((Term("error"), Term("block"))), Term("node9"))),
]


def _truth(docs):
    truth: dict[str, set[int]] = {}
    for i, d in enumerate(docs):
        for w in distinct_words(d):
            truth.setdefault(w, set()).add(i)
    return truth


@pytest.fixture(scope="module")
def corpora():
    store = InMemoryBlobStore()
    docs1 = make_logs_like(900, seed=21)
    docs2 = make_logs_like(250, seed=22)
    c1 = write_corpus(store, "corpus/one", docs1, n_blobs=3)
    c2 = write_corpus(store, "corpus/two", docs2, n_blobs=2)
    return store, docs1, docs2, c1, c2


# ------------------------------------------------------------ build / open
def test_build_open_roundtrip_and_manifest(corpora):
    store, docs1, _docs2, c1, _c2 = corpora
    idx = Index.build(c1, CFG, store, "index/bo")
    assert idx.generation == 1 and idx.n_segments == 0
    assert idx.report is not None and idx.report.n_docs == len(docs1)
    # manifest blob round-trips through its codec
    raw = store.get(f"index/bo/manifest-00000001.airm")
    m = decode_manifest(raw)
    assert m["generation"] == 1 and m["base"]["prefix"] == "index/bo"
    assert decode_manifest(encode_manifest(m)) == m

    opened = Index.open(SimCloudStore(store, seed=3), "index/bo")
    assert opened.generation == 1
    assert opened.config == CFG
    s = opened.searcher()
    assert isinstance(s, Searcher)        # no segments -> classic engine
    truth = _truth(docs1)
    res = s.query("error")
    assert set(res.texts) == {docs1[i] for i in truth["error"]}


def test_open_missing_prefix_raises(corpora):
    store, *_ = corpora
    with pytest.raises(FileNotFoundError):
        Index.open(store, "index/does-not-exist")


def test_legacy_searcher_constructor_raises_typed_error(corpora):
    store, _docs1, _docs2, c1, _c2 = corpora
    Index.build(c1, CFG, store, "index/legacy")
    with pytest.raises(DeprecatedAPIError, match="StorageTransport"):
        Searcher(SimCloudStore(store, seed=5), "index/legacy")


def test_legacy_searcher_constructor_identical_under_flag(corpora,
                                                          monkeypatch):
    monkeypatch.setenv("REPRO_ALLOW_DEPRECATED", "1")
    store, _docs1, _docs2, c1, _c2 = corpora
    Index.build(c1, CFG, store, "index/legacy")
    facade = Index.open(SimCloudStore(store, seed=5),
                        "index/legacy").searcher()
    with pytest.warns(DeprecationWarning):
        legacy = Searcher(SimCloudStore(store, seed=5), "index/legacy")
    a = facade.query_batch(MIXED)
    b = legacy.query_batch(MIXED)
    for x, y in zip(a, b):
        assert x.texts == y.texts and x.refs == y.refs


def test_legacy_header_only_prefix_opens_read_only(corpora):
    store, docs1, _docs2, c1, _c2 = corpora
    from repro.index import Builder
    Builder(CFG).build(c1, store, "index/oldstyle")   # no manifest
    idx = Index.open(store, "index/oldstyle")
    assert idx.generation == 0 and idx.config is None
    res = idx.searcher().query("error")
    assert set(res.texts) == {docs1[i] for i in _truth(docs1)["error"]}
    with pytest.raises(ValueError):
        idx.writer()


# --------------------------------------------- segments: the identity test
def test_append_commit_reopen_identical_to_monolithic_rebuild(corpora):
    store, docs1, docs2, c1, c2 = corpora
    idx = Index.build(c1, CFG, store, "index/seg")
    w = idx.writer()
    rep = w.append(c2)
    assert rep.n_docs == len(docs2)
    assert idx.n_segments == 0            # staged, not yet visible
    w.commit()
    assert idx.generation == 2 and idx.n_segments == 1

    reopened = Index.open(SimCloudStore(store, seed=4), "index/seg")
    seg = reopened.searcher()
    assert isinstance(seg, MultiSegmentSearcher) and seg.n_units == 2

    cat = Corpus(store=store, refs=c1.refs + c2.refs, texts=docs1 + docs2)
    Index.build(cat, CFG, store, "index/mono")
    mono = Index.open(SimCloudStore(store, seed=4), "index/mono").searcher()

    queries = MIXED + [Regex(r"blk_4[0-9]1\b")]
    a = seg.query_batch(queries)
    b = mono.query_batch(queries)
    for q, x, y in zip(queries, a, b):
        assert x.texts == y.texts, q
        assert x.refs == y.refs, q
    # ground truth over the concatenated corpus, for good measure
    alldocs = docs1 + docs2
    truth = _truth(alldocs)
    assert set(a[0].texts) == {alldocs[i] for i in truth["error"]}


def test_topk_over_segments_returns_k_matching(corpora):
    store, *_ = corpora
    seg = Index.open(SimCloudStore(store, seed=4), "index/seg").searcher()
    for res in seg.query_batch(["error", "info"], top_k=5):
        assert len(res.texts) == 5 and len(res.refs) == 5
    for res, w in zip(seg.query_batch(["error", "info"], top_k=5),
                      ["error", "info"]):
        assert all(w in distinct_words(t) for t in res.texts)


def test_merge_compacts_to_single_base_identical(corpora):
    store, docs1, docs2, c1, c2 = corpora
    idx = Index.build(c1, CFG, store, "index/mrg")
    w = idx.writer()
    w.append(c2)
    w.commit()
    before = Index.open(SimCloudStore(store, seed=6),
                        "index/mrg").searcher().query_batch(MIXED)
    w.merge()
    assert idx.generation == 3 and idx.n_segments == 0
    merged = Index.open(SimCloudStore(store, seed=6), "index/mrg")
    s = merged.searcher()
    assert isinstance(s, Searcher)        # compacted back to one unit
    assert merged.base_prefix == "index/mrg/base-00000003"
    after = s.query_batch(MIXED)
    for x, y in zip(after, before):
        assert x.texts == y.texts and x.refs == y.refs


def test_abort_deletes_staged_segment_blobs(corpora):
    store, _docs1, _docs2, c1, c2 = corpora
    idx = Index.build(c1, CFG, store, "index/abort")
    w = idx.writer()
    w.append(c2)
    staged = [n for n in store.list("index/abort/seg-")]
    assert staged                          # blobs written but unreferenced
    w.abort()
    assert not store.list("index/abort/seg-")
    assert idx.generation == 1             # nothing committed
    # readers never saw the staged segment
    s = Index.open(store, "index/abort").searcher()
    assert isinstance(s, Searcher)


def test_concurrent_commit_detected(corpora):
    store, docs1, docs2, c1, c2 = corpora
    Index.build(c1, CFG, store, "index/race")
    w_a = Index.open(store, "index/race").writer()
    w_b = Index.open(store, "index/race").writer()
    w_a.append(c2)
    w_b.append(c2)
    # sessions stage to disjoint blob names (per-session token), so the
    # loser can neither overwrite nor abort() away the winner's segment
    a_blobs = set(store.list(w_a._staged_prefixes[0]))
    b_blobs = set(store.list(w_b._staged_prefixes[0]))
    assert a_blobs and b_blobs and a_blobs.isdisjoint(b_blobs)
    w_a.commit()
    with pytest.raises(RuntimeError, match="concurrent"):
        w_b.commit()
    w_b.abort()
    alldocs = docs1 + docs2
    res = Index.open(store, "index/race").searcher().query("error")
    assert set(res.texts) == {alldocs[i] for i in _truth(alldocs)["error"]}


def test_put_if_absent_atomic_create(tmp_path):
    from repro.storage import LocalBlobStore
    mem = InMemoryBlobStore()
    assert mem.put_if_absent("m", b"winner") is True
    assert mem.put_if_absent("m", b"loser") is False
    assert mem.get("m") == b"winner"
    loc = LocalBlobStore(str(tmp_path))
    assert loc.put_if_absent("d/m", b"winner") is True
    assert loc.put_if_absent("d/m", b"loser") is False
    assert loc.get("d/m") == b"winner"
    assert not [n for n in loc.list("") if ".tmp." in n]


def test_commit_publication_is_compare_and_swap(corpora):
    """Even a writer that passes the generation check must lose the
    publish if a racer claimed the generation in between — put_if_absent
    is the linearization point, never a silent overwrite."""
    store, _docs1, _docs2, c1, c2 = corpora
    idx = Index.build(c1, CFG, store, "index/cas")
    w = Index.open(store, "index/cas").writer()
    w.append(c2)
    from repro.index.lifecycle import _manifest_name, encode_manifest
    racer = dict(idx.manifest, generation=2)
    store.put(_manifest_name("index/cas", 2), encode_manifest(racer))
    w._check_not_raced = lambda: 2     # interleave: check already passed
    with pytest.raises(RuntimeError, match="concurrent"):
        w.commit()
    assert store.get(_manifest_name("index/cas", 2)) == \
        encode_manifest(racer)         # winner's manifest untouched


# ------------------------------------------------ generation-keyed caches
def test_superpost_cache_is_generation_keyed():
    spc = SuperpostCache(1 << 20)
    spc.put("b", 0, 4, b"gen1", generation=1)
    assert spc.get("b", 0, 4, generation=1) == b"gen1"
    assert spc.get("b", 0, 4, generation=2) is None   # never cross-gen
    spc.put("b", 0, 4, b"gen2", generation=2)
    assert spc.get("b", 0, 4, generation=2) == b"gen2"
    assert spc.get("b", 0, 4, generation=1) == b"gen1"


def test_inplace_rebuild_cannot_serve_stale_superposts(corpora):
    """Regression: an in-place rebuild reuses the SAME blob names (and
    often the same ranges); a shared SuperpostCache must miss across the
    generation bump instead of serving pre-rebuild bytes."""
    store, _d1, _d2, _c1, _c2 = corpora
    docs_a = make_logs_like(400, seed=31)
    docs_b = make_logs_like(400, seed=32)
    ca = write_corpus(store, "corpus/ra", docs_a, n_blobs=2)
    spc = SuperpostCache(8 << 20)
    idx1 = Index.build(ca, CFG, store, "index/rebuild")
    s1 = idx1.searcher(cache=spc)
    s1.query_batch(["error", "info", "block"])      # warm the cache
    assert spc.cached_bytes > 0

    cb = write_corpus(store, "corpus/ra", docs_b, n_blobs=2)  # same blobs!
    idx2 = Index.build(cb, CFG, store, "index/rebuild")
    assert idx2.generation == idx1.generation + 1
    cached = idx2.searcher(cache=spc).query_batch(["error", "info", "block"])
    fresh = idx2.searcher().query_batch(["error", "info", "block"])
    for x, y in zip(cached, fresh):
        assert x.texts == y.texts and x.refs == y.refs
    truth_b = _truth(docs_b)
    assert set(cached[0].texts) == {docs_b[i] for i in truth_b["error"]}


def test_service_result_cache_invalidated_by_commit(corpora):
    """Regression: the SearchService result LRU is keyed by generation,
    so a writer.commit() + refresh() re-executes instead of serving the
    pre-commit QueryResult."""
    store, docs1, docs2, c1, c2 = corpora
    idx = Index.build(c1, CFG, store, "index/svc")
    svc = SearchService(idx, cache_size=8, superpost_cache_bytes=4 << 20)
    assert svc.generation == 1 and svc.refresh() is False
    r1 = svc.search("error")
    assert svc.search("error") is r1       # same-generation hit
    assert svc.cache_hits == 1

    w = idx.writer()
    w.append(c2)
    w.commit()
    # between commit and refresh the service still serves (and caches
    # under) its pinned old-generation snapshot — never a mixed state
    assert svc.search("error") is r1
    assert svc.cache_hits == 2
    assert svc.refresh() is True and svc.generation == 2
    assert isinstance(svc.searcher, MultiSegmentSearcher)
    r2 = svc.search("error")               # miss: key carries generation
    assert svc.cache_hits == 2
    alldocs = docs1 + docs2
    assert set(r2.texts) == {alldocs[i] for i in _truth(alldocs)["error"]}
    assert len(r2.texts) > len(r1.texts)
    assert svc.search("error") is r2       # new generation caches again
    assert svc.cache_hits == 3


# ------------------------------------------------------- transport protocol
class _FlakyStore(InMemoryBlobStore):
    """Fails the first read attempt of every distinct range."""

    def __init__(self):
        super().__init__()
        self._seen: set = set()
        self._flaky_lock = threading.Lock()
        self.failures = 0

    def get_range(self, req):
        key = (req.blob, req.offset, req.length)
        with self._flaky_lock:
            first = key not in self._seen
            self._seen.add(key)
            if first:
                self.failures += 1
        if first:
            raise OSError(f"transient read error for {key}")
        return super().get_range(req)


def test_blobstore_transport_retry_accounting():
    store = _FlakyStore()
    store.put("blob", bytes(range(256)))
    reqs = [RangeRequest("blob", 0, 16), RangeRequest("blob", 16, 16),
            RangeRequest("blob", 100, 8)]
    transport = BlobStoreTransport(store, TransportPolicy(max_retries=2))
    payloads, stats = transport.fetch_batch(reqs)
    assert payloads == [bytes(range(0, 16)), bytes(range(16, 32)),
                        bytes(range(100, 108))]
    assert stats.n_retries == 3            # one re-issue per request
    assert stats.n_requests == 6           # 3 GETs + 3 retries
    assert stats.bytes_fetched == 40


def test_blobstore_transport_exhausted_retries_raise():
    store = _FlakyStore()
    store.put("blob", b"x" * 64)
    transport = BlobStoreTransport(store)      # max_retries=0
    with pytest.raises(TransportError):
        transport.fetch(RangeRequest("blob", 0, 8))


def test_sim_transport_default_policy_is_passthrough(corpora):
    """Default-policy transport == raw fetch_batch: same clock, same RNG
    stream, same payloads — the invariant that keeps every pre-transport
    latency test meaningful."""
    store, *_ = corpora
    reqs = [RangeRequest(n, 0, 64) for n in store.list("corpus/one/")]
    raw = SimCloudStore(store, seed=17)
    via = SimCloudStore(store, seed=17)
    p1, s1 = raw.fetch_batch(reqs)
    p2, s2 = SimCloudTransport(via).fetch_batch(reqs)
    assert p1 == p2
    assert s1.elapsed_s == s2.elapsed_s and raw.clock_s == via.clock_s


def test_sim_transport_hedged_get_accounting(corpora):
    """Hedged duplicate GETs: byte-identical payloads, tail latency cut,
    hedge counters threaded into FetchStats and store totals."""
    store, *_ = corpora
    from repro.storage import NetworkModel
    tail_model = NetworkModel(tail_prob=0.30, tail_scale=12.0)
    reqs = [RangeRequest(n, 0, 128) for n in store.list("corpus/")] * 4

    plain_cloud = SimCloudStore(store, model=tail_model, seed=8)
    plain, _ = plain_cloud.fetch_batch(reqs)

    cloud = SimCloudStore(store, model=tail_model, seed=8)
    policy = TransportPolicy(hedge_after_s=2.0 * tail_model.first_byte_s)
    payloads, stats = SimCloudTransport(cloud, policy).fetch_batch(reqs)
    assert payloads == plain                   # same bytes, always
    assert stats.n_hedges_issued > 0
    assert stats.n_hedge_wins > 0
    assert stats.n_requests == len(reqs) + stats.n_hedges_issued
    assert cloud.totals.n_hedges_issued == stats.n_hedges_issued
    # a straggler beaten by its duplicate cannot be slower than unhedged
    assert stats.wait_s <= plain_cloud.totals.wait_s + 1e-12


def test_sim_transport_deadline_retry_accounting(corpora):
    store, *_ = corpora
    from repro.storage import NetworkModel
    tail_model = NetworkModel(tail_prob=0.5, tail_scale=20.0)
    cloud = SimCloudStore(store, model=tail_model, seed=8)
    reqs = [RangeRequest(n, 0, 64) for n in store.list("corpus/")] * 3
    policy = TransportPolicy(deadline_s=2.0 * tail_model.first_byte_s,
                             max_retries=2)
    payloads, stats = SimCloudTransport(cloud, policy).fetch_batch(reqs)
    assert all(p is not None for p in payloads)
    assert stats.n_retries > 0
    assert stats.n_requests == len(reqs) + stats.n_retries


def test_searcher_accepts_transport_without_warning(corpora):
    store, docs1, _docs2, _c1, _c2 = corpora
    transport = as_transport(SimCloudStore(store, seed=2))
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        s = Searcher(transport, "index/bo")
        svc = SearchService(transport, "index/bo")
    truth = _truth(docs1)
    assert set(s.query("error").texts) == \
        {docs1[i] for i in truth["error"]}
    assert set(svc.search("error").texts) == \
        {docs1[i] for i in truth["error"]}


def test_service_legacy_constructor_raises(corpora, monkeypatch):
    store, *_ = corpora
    with pytest.raises(DeprecatedAPIError, match="StorageTransport"):
        SearchService(SimCloudStore(store, seed=2), "index/bo")
    # the compat flag restores the old warn-and-work shim
    monkeypatch.setenv("REPRO_ALLOW_DEPRECATED", "1")
    with pytest.warns(DeprecationWarning):
        SearchService(SimCloudStore(store, seed=2), "index/bo")


# ------------------------------------------------- multi-segment internals
def test_multisegment_shares_fetch_rounds(corpora):
    """A segmented lookup is still two shared rounds, not two per unit —
    and opening the reader fetches every unit's header in ONE batch."""
    store, *_ = corpora
    cloud = SimCloudStore(store, seed=12)
    seg = Index.open(cloud, "index/seg").searcher()
    assert seg.init_stats.n_requests == seg.n_units   # one parallel round
    res = seg.query(And((Term("info"), Term("block"))))
    assert res.texts                       # non-empty: a doc round ran
    assert res.stats.rounds == 2


def test_index_close_and_context_manager(corpora):
    store, docs1, *_ = corpora
    with Index.open(store, "index/bo") as idx:       # owns its transport
        res = idx.searcher().query("error")
        assert set(res.texts) == {docs1[i] for i in _truth(docs1)["error"]}
    idx.close()                                      # idempotent
    transport = as_transport(SimCloudStore(store, seed=3))
    svc = SearchService(Index.open(transport, "index/bo"))
    svc.search("error")
    svc.close()          # caller-supplied transport stays the caller's
    assert svc.search("info").stats.n_results >= 0


def test_multisegment_lookup_batch_shape(corpora):
    store, *_ = corpora
    seg = Index.open(SimCloudStore(store, seed=12),
                     "index/seg").searcher()
    # per-unit lookups live under a distinct name — the Searcher-shaped
    # `lookup`/`lookup_batch` deliberately do not exist on the multi-
    # segment reader (per-unit keys index per-unit string tables)
    assert not hasattr(seg, "lookup") and not hasattr(seg, "lookup_batch")
    outs, stats = seg.lookup_batch_units(["error", "info"])
    assert len(outs) == seg.n_units
    for unit_outs in outs:
        assert len(unit_outs) == 2
        assert set(unit_outs[0]) == {"error"}
    assert stats.n_candidates > 0
    assert isinstance(stats.lookup.n_requests, int)
    assert np.all(np.diff(outs[0][0]["error"][0].astype(np.int64)) > 0)
