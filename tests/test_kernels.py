"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp ref."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import attention, flash_attention, attention_ref
from repro.kernels.intersect import (bitmap_to_docs, intersect,
                                     postings_to_bitmap)
from repro.kernels.rwkv import wkv, wkv_ref
from repro.kernels.ssm import selective_scan, selective_scan_ref


# ---------------------------------------------------------------- intersect
@pytest.mark.parametrize("L,n_docs", [(1, 100), (2, 4096), (3, 40_000),
                                      (4, 33_000), (2, 31)])
def test_intersect_vs_ref_and_sets(L, n_docs):
    rng = np.random.default_rng(L * 1000 + n_docs)
    posts = [np.unique(rng.integers(0, n_docs, max(n_docs // 4, 2)))
             .astype(np.uint32) for _ in range(L)]
    bm = postings_to_bitmap(posts, n_docs)
    out_p, cnt_p = intersect(bm, impl="pallas")
    out_r, cnt_r = intersect(bm, impl="ref")
    assert (np.asarray(out_p) == np.asarray(out_r)).all()
    assert int(cnt_p) == int(cnt_r)
    expect = set(posts[0].tolist())
    for p in posts[1:]:
        expect &= set(p.tolist())
    assert set(bitmap_to_docs(np.asarray(out_p)).tolist()) == expect
    assert int(cnt_p) == len(expect)


def test_intersect_empty():
    bm = postings_to_bitmap([np.array([1], np.uint32),
                             np.array([2], np.uint32)], 64)
    out, cnt = intersect(bm, impl="pallas")
    assert int(cnt) == 0 and not np.asarray(out).any()


# ---------------------------------------------------------------- attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,T,dh,causal,window", [
    (1, 2, 2, 128, 128, 64, True, None),
    (2, 4, 2, 256, 256, 64, True, None),       # GQA
    (1, 2, 1, 128, 256, 128, True, None),      # MQA, decode-ish S<T
    (2, 2, 2, 256, 256, 64, True, 128),        # sliding window
    (1, 2, 2, 128, 128, 64, False, None),      # bidirectional
])
def test_flash_attention_vs_ref(B, H, KV, S, T, dh, causal, window, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, T, KV, dh)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, T, KV, dh)), dtype)
    out_p = attention(q, k, v, causal=causal, window=window, impl="pallas")
    out_r = attention(q, k, v, causal=causal, window=window, impl="ref")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), atol=tol)


# --------------------------------------------------------------------- rwkv
@pytest.mark.parametrize("B,S,H,dh", [(1, 128, 2, 32), (2, 256, 3, 64),
                                      (1, 64, 1, 128)])
def test_wkv_vs_ref(B, S, H, dh):
    rng = np.random.default_rng(B + S)
    r = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 0.3, (B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 0.999, (B, S, H, dh)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 0.3, (H, dh)), jnp.float32)
    out_p = wkv(r, k, v, w, u, impl="pallas")
    out_r = wkv(r, k, v, w, u, impl="ref")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)


def test_wkv_model_chunked_matches_kernel_ref():
    """The model's two-level chunked wkv == the sequential oracle."""
    from repro.models.rwkv6 import wkv_chunked
    rng = np.random.default_rng(7)
    B, S, H, dh = 2, 96, 2, 16
    r = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 0.3, (B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, H, dh)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 0.3, (H, dh)), jnp.float32)
    s0 = jnp.asarray(rng.normal(0, 0.1, (B, H, dh, dh)), jnp.float32)
    out_c, s_c = wkv_chunked(r, k, v, jnp.log(w), u, s0, chunk=32)
    out_r, s_r = wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------- ssm
@pytest.mark.parametrize("B,S,D,N", [(1, 64, 128, 8), (2, 128, 256, 16),
                                     (1, 192, 384, 4)])
def test_selective_scan_vs_ref(B, S, D, N):
    rng = np.random.default_rng(B * S)
    a = jnp.asarray(rng.uniform(0.4, 0.99, (B, S, D, N)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.3, (B, S, D, N)), jnp.float32)
    c = jnp.asarray(rng.normal(0, 1, (B, S, N)), jnp.float32)
    y_p = selective_scan(a, b, c, impl="pallas")
    y_r = selective_scan(a, b, c, impl="ref")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


def test_mamba_model_chunked_matches_ref():
    """The model's chunked diagonal scan == the sequential oracle."""
    from repro.models.mamba import chunked_diag_scan
    rng = np.random.default_rng(3)
    B, S, D, N = 2, 96, 32, 8
    a = jnp.asarray(rng.uniform(0.4, 0.99, (B, S, D, N)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.3, (B, S, D, N)), jnp.float32)
    h0 = jnp.asarray(rng.normal(0, 0.1, (B, D, N)), jnp.float32)
    h_all, h_fin = chunked_diag_scan(a, b, h0, chunk=32)
    # sequential reference with h0
    import jax
    def step(h, xs):
        a_t, b_t = xs
        h = a_t * h + b_t
        return h, h
    h_ref_fin, h_ref = jax.lax.scan(
        step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    np.testing.assert_allclose(np.asarray(h_all),
                               np.asarray(jnp.moveaxis(h_ref, 0, 1)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(h_ref_fin),
                               rtol=1e-5, atol=1e-5)
