"""Multi-device integration tests.

These run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps seeing 1 device (the dry-run contract).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> dict:
    # pin the cpu platform explicitly: the forced host device count still
    # applies, and an unset JAX_PLATFORMS would probe the container's TPU
    # PJRT plugin, which hangs for minutes when no TPU is attached
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


PREAMBLE = """
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import build_model, init_params, rules_for
from repro.launch.mesh import make_smoke_mesh
"""


def test_sharded_train_step_matches_single_device():
    """Same loss on a (4,2) mesh as on 1 device — sharding is semantics-
    preserving."""
    result = _run(PREAMBLE + textwrap.dedent("""
        from repro.models import NULL_RULES
        from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state
        cfg = get_config("qwen3-32b", reduced=True)
        model = build_model(cfg)
        params = init_params(model.param_desc(), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(4, cfg.vocab, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(4, cfg.vocab, (8, 32)), jnp.int32)}
        loss1 = float(jax.jit(lambda p, b: model.loss_fn(p, b, NULL_RULES))(params, batch))

        mesh = make_smoke_mesh(8, model=2)
        rules = rules_for(mesh)
        shard = rules.sharding_tree(model.param_desc())
        params_s = jax.device_put(params, shard)
        from jax.sharding import NamedSharding, PartitionSpec as P
        bsh = NamedSharding(mesh, P("data", None))
        batch_s = {k: jax.device_put(v, bsh) for k, v in batch.items()}
        loss2 = float(jax.jit(lambda p, b: model.loss_fn(p, b, rules))(params_s, batch_s))
        print(json.dumps({"loss1": loss1, "loss2": loss2,
                          "n_dev": len(jax.devices())}))
    """))
    assert result["n_dev"] == 8
    assert abs(result["loss1"] - result["loss2"]) < 0.05, result


def test_sharded_moe_and_decode():
    result = _run(PREAMBLE + textwrap.dedent("""
        cfg = get_config("phi3.5-moe-42b-a6.6b", reduced=True)
        model = build_model(cfg)
        mesh = make_smoke_mesh(8, model=2)
        rules = rules_for(mesh)
        params = init_params(model.param_desc(), jax.random.PRNGKey(0))
        params = jax.device_put(params, rules.sharding_tree(model.param_desc()))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(4, cfg.vocab, (8, 16)), jnp.int32)
        logits, cache = jax.jit(lambda p, b: model.prefill(p, b, rules, pad_to=24))(
            params, {"tokens": toks})
        l2, cache = jax.jit(lambda p, c, b: model.decode_step(p, c, b, rules))(
            params, cache, {"tokens": toks[:, :1]})
        ok = bool(jnp.isfinite(l2).all())
        print(json.dumps({"ok": ok, "shape": list(l2.shape)}))
    """))
    assert result["ok"] and result["shape"][1] == 512


def test_elastic_checkpoint_reshard():
    """Save params from a (4,2) mesh, restore onto (2,4) — elasticity."""
    result = _run(PREAMBLE + textwrap.dedent("""
        from repro.storage import InMemoryBlobStore
        from repro.training import CheckpointManager
        from repro.launch.elastic import choose_mesh, reshard_restore
        from repro.training.optimizer import init_opt_state
        cfg = get_config("granite-20b", reduced=True)
        model = build_model(cfg)
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        rules_a = rules_for(mesh_a)
        params = init_params(model.param_desc(), jax.random.PRNGKey(3))
        params = jax.device_put(params, rules_a.sharding_tree(model.param_desc()))
        state = {"params": params, "opt": init_opt_state(params)}
        store = InMemoryBlobStore()
        ckpt = CheckpointManager(store)
        ckpt.save(11, state)

        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        restored, manifest = reshard_restore(ckpt, model, mesh_b)
        w_a = np.asarray(params["lm_head"], np.float32)
        w_b = np.asarray(restored["params"]["lm_head"], np.float32)
        same = bool((w_a == w_b).all())
        shard_ok = restored["params"]["lm_head"].sharding.mesh.shape["model"] == 4
        print(json.dumps({"same": same, "step": manifest["step"],
                          "shard_ok": bool(shard_ok)}))
    """))
    assert result["same"] and result["step"] == 11 and result["shard_ok"]


def test_pipeline_parallel_stage():
    """GPipe-style shard_map pipeline over a 'pipe' axis: outputs match the
    unpipelined reference."""
    result = _run(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.pipeline import pipelined_mlp, reference_mlp
        n_stages, n_micro, d = 4, 8, 32
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(0, 0.3, (n_stages, d, d)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (n_micro * 4, d)), jnp.float32)
        mesh = jax.make_mesh((n_stages,), ("pipe",))
        y_pipe = pipelined_mlp(mesh, ws, x, n_micro=n_micro)
        y_ref = reference_mlp(ws, x)
        err = float(jnp.max(jnp.abs(y_pipe - y_ref)))
        print(json.dumps({"err": err}))
    """))
    assert result["err"] < 1e-4
