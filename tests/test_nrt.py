"""NRT ingest subsystem: memory-resident segments, generation
notifications, and lease-based GC (index/nrt.py + serving/notify.py).

Load-bearing acceptance criteria: (1) a document staged by
`IndexWriter.add()` is returned by `SearchService.search` BEFORE
`commit()` publishes blobs, and the pre-publish results are
byte-identical to the post-publish + refresh results — single index and
sharded cluster; (2) `collect_garbage` never deletes a blob reachable
from a leased generation, even with `grace_s=0.0` (property-tested over
random add/commit/refresh/gc interleavings); (3) push-notified swaps
cost zero range reads when nothing durable changed."""

import threading
import warnings

import pytest
from _hypothesis_compat import given, settings, st

from repro.compat import UngracedSweepError
from repro.data import make_logs_like, write_corpus
from repro.data.tokenizer import distinct_words
from repro.index import (And, BuilderConfig, Index, LeaseRegistry,
                         MultiSegmentSearcher, Or, Term)
from repro.index.lifecycle import collect_garbage, reachable_blobs
from repro.serving import (Frontend, FrontendConfig, GenerationBus,
                           GenerationEvent, SearchService, ShardedIndex,
                           collect_cluster_garbage)
from repro.storage import InMemoryBlobStore

CFG = BuilderConfig(B=1200, F0=1.0, hedge_layers=1, index_ngrams=3)

MIXED = [
    "error", "info", "block",
    And((Term("error"), Term("block"))),
    Or((Term("warn"), Term("node7"))),
    Or((And((Term("error"), Term("block"))), Term("node9"))),
]


class CountingStore(InMemoryBlobStore):
    """InMemoryBlobStore that counts range reads (the data plane a
    refresh must NOT touch when nothing durable changed)."""

    def __init__(self):
        super().__init__()
        self.n_reads = 0

    def get_range(self, req):
        self.n_reads += 1
        return super().get_range(req)


def _identical(a, b):
    assert len(a) == len(b)
    return all(x.texts == y.texts and x.refs == y.refs
               for x, y in zip(a, b))


def _fixture(n1=700, n2=180, store=None):
    store = store or InMemoryBlobStore()
    docs1 = make_logs_like(n1, seed=71)
    docs2 = make_logs_like(n2, seed=72)
    c1 = write_corpus(store, "corpus/nrt1", docs1, n_blobs=3)
    c2 = write_corpus(store, "corpus/nrt2", docs2, n_blobs=2)
    return store, docs1, docs2, c1, c2


def _word_only_in(docs2, docs1):
    """A query word present in docs2 but absent from docs1."""
    have = set()
    for d in docs1:
        have |= distinct_words(d)
    for d in docs2:
        for w in distinct_words(d):
            if w not in have:
                return w
    raise AssertionError("fixtures overlap completely")


# ------------------------------------------------ pre-publish byte identity
def test_add_visible_before_publish_and_identical_after(tmp_path=None):
    store, docs1, docs2, c1, c2 = _fixture()
    idx = Index.build(c1, CFG, store, "index/nrt")
    svc = SearchService(idx, cache_size=32)
    fresh_word = _word_only_in(docs2, docs1)
    assert svc.search(fresh_word).texts == []     # not ingested yet

    w = idx.writer()
    rep = w.add(c2)
    assert rep.n_docs == len(docs2)
    # nothing durable happened: no segment blobs, no new manifest
    assert store.list("index/nrt/seg-") == []
    assert idx.generation == 1
    # ...but the documents are already searchable through this handle
    assert svc.refresh() is True
    pre = svc.search_batch(MIXED + [fresh_word])
    expect_fresh = {d for d in docs2 if fresh_word in distinct_words(d)}
    assert set(pre[-1].texts) == expect_fresh and expect_fresh

    w.commit()
    assert idx.generation == 2
    assert store.list("index/nrt/seg-") != []     # now durable
    assert svc.refresh() is True
    post = svc.search_batch(MIXED + [fresh_word])
    assert _identical(pre, post)                  # byte-identical swap

    # a cold reader over the published store agrees exactly
    cold = SearchService(Index.open(store, "index/nrt"))
    assert _identical(pre, cold.search_batch(MIXED + [fresh_word]))


def test_memory_segment_publish_is_byte_identical(tmp_path=None):
    store, _docs1, _docs2, c1, c2 = _fixture(n1=120, n2=120)
    idx = Index.build(c1, CFG, store, "index/pubbytes")
    w = idx.writer()
    w.add(c2)
    seg = idx.memory_segments[0]
    staged = {name: seg._staging.get(name) for name in seg.blob_names()}
    assert staged and seg.staged_bytes == sum(len(v) for v in staged.values())
    w.commit()
    for name, data in staged.items():
        assert store.get(name) == data            # the very same bytes


def test_abort_retracts_memory_segments(tmp_path=None):
    store, docs1, docs2, c1, c2 = _fixture(n1=120, n2=120)
    idx = Index.build(c1, CFG, store, "index/abort")
    svc = SearchService(idx)
    fresh_word = _word_only_in(docs2, docs1)
    w = idx.writer()
    w.add(c2)
    svc.refresh()
    assert svc.search(fresh_word).texts != []
    w.abort()
    assert idx.memory_segments == []
    assert svc.refresh() is True
    assert svc.search(fresh_word).texts == []


def test_cluster_add_visible_before_publish_and_identical(tmp_path=None):
    store, docs1, docs2, c1, c2 = _fixture(n1=900, n2=450)
    cluster = ShardedIndex.build(c1, CFG, store, "cluster/nrt", n_shards=3)
    svc = SearchService(cluster, cache_size=32)
    fresh_word = _word_only_in(docs2, docs1)
    assert svc.search(fresh_word).texts == []

    # route the delta the same way cluster.append would, but stage each
    # shard's slice as a MEMORY segment through the shard writer
    writers = []
    for s, part in enumerate(cluster.partition(c2)):
        if part.refs:
            assert cluster.shards[s] is not None
            w = cluster.shard(s).writer()
            w.add(part)
            writers.append(w)
    assert writers
    assert svc.refresh() is True
    pre = svc.search_batch(MIXED + [fresh_word])
    expect_fresh = {d for d in docs2 if fresh_word in distinct_words(d)}
    assert set(pre[-1].texts) == expect_fresh and expect_fresh

    for w in writers:
        w.commit()
    assert svc.refresh() is True
    post = svc.search_batch(MIXED + [fresh_word])
    assert _identical(pre, post)

    cold = SearchService(ShardedIndex.open(store, "cluster/nrt"))
    assert _identical(pre, cold.search_batch(MIXED + [fresh_word]))
    cold.close()
    svc.close()


# ----------------------------------------------------- O(1) no-op refreshes
def test_refresh_is_zero_read_noop_and_swap_is_zero_read(tmp_path=None):
    store = CountingStore()
    _store, docs1, docs2, c1, c2 = _fixture(n1=120, n2=120, store=store)
    idx = Index.build(c1, CFG, store, "index/cheap")
    svc = SearchService(idx)

    n0 = store.n_reads
    for _ in range(3):
        assert svc.refresh() is False     # unchanged: LIST only
    assert store.n_reads == n0

    w = idx.writer()
    w.add(c2)
    n1 = store.n_reads                    # (add read corpus text blobs)
    assert svc.refresh() is True          # memory swap: zero range reads
    assert store.n_reads == n1
    assert isinstance(svc.searcher, MultiSegmentSearcher)

    w.commit()
    n2 = store.n_reads
    assert svc.refresh() is True          # publish swap: headers cached,
    assert store.n_reads == n2            # manifest already in-handle

    # a FRESH handle still pays its boot reads (the cache is per-handle)
    before = store.n_reads
    SearchService(Index.open(store, "index/cheap"))
    assert store.n_reads > before


def test_sharded_refresh_is_zero_read_noop(tmp_path=None):
    store = CountingStore()
    _store, _docs1, _docs2, c1, _c2 = _fixture(n1=200, n2=30, store=store)
    cluster = ShardedIndex.build(c1, CFG, store, "cluster/cheap",
                                 n_shards=2)
    n0 = store.n_reads
    cluster.refresh()
    assert store.n_reads == n0


# ------------------------------------------------------------ notifications
def test_bus_stepped_buffers_until_drain(tmp_path=None):
    bus = GenerationBus()
    seen = []
    bus.subscribe(seen.append)
    bus.post(GenerationEvent(prefix="p", kind="memory", generation=1,
                             seq=1))
    bus.post_generation(prefix="p", kind="published", generation=2)
    assert seen == [] and bus.pending == 2
    assert bus.drain() == 2
    assert [e.kind for e in seen] == ["memory", "published"]
    assert bus.n_delivered == 2 and bus.pending == 0


def test_bus_threaded_delivers_async(tmp_path=None):
    bus = GenerationBus(threaded=True)
    got = threading.Event()
    seen = []

    def on_event(e):
        seen.append(e)
        got.set()

    bus.subscribe(on_event)
    bus.post_generation(prefix="p", kind="published", generation=3)
    assert got.wait(timeout=5.0)
    assert seen[0].generation == 3
    bus.close()


def test_bus_callback_errors_are_counted_not_raised(tmp_path=None):
    bus = GenerationBus()
    ok = []

    def bad(_e):
        raise RuntimeError("boom")

    bus.subscribe(bad)
    bus.subscribe(ok.append)
    bus.post_generation(prefix="p", kind="memory", generation=1)
    bus.drain()
    assert bus.n_callback_errors == 1 and len(ok) == 1


def test_service_follows_bus_push_swap(tmp_path=None):
    store, docs1, docs2, c1, c2 = _fixture(n1=120, n2=120)
    idx = Index.build(c1, CFG, store, "index/follow")
    bus = GenerationBus()
    idx.attach_bus(bus)
    svc = SearchService(idx).follow(bus)
    fresh_word = _word_only_in(docs2, docs1)
    w = idx.writer()
    w.add(c2)
    assert svc.search(fresh_word).texts == []     # not yet delivered
    bus.drain()                                   # push-triggered swap
    pre = svc.search(fresh_word)
    assert pre.texts != []
    w.commit()
    bus.drain()
    assert svc.search(fresh_word).texts == pre.texts


def test_frontend_follow_swaps_at_batch_boundary(tmp_path=None):
    store, docs1, docs2, c1, c2 = _fixture(n1=120, n2=120)
    idx = Index.build(c1, CFG, store, "index/fefollow")
    bus = GenerationBus()
    idx.attach_bus(bus)
    svc = SearchService(idx)
    fe = Frontend(svc, FrontendConfig(max_queue=8)).follow(bus)
    fresh_word = _word_only_in(docs2, docs1)
    idx.writer().add(c2)
    bus.drain()                  # flags the refresh; swap is deferred
    fut = fe.submit(fresh_word)
    fe.run_once()                # ...to the next batch boundary
    assert fut.result().texts != []
    fe.close()


# ------------------------------------------------------------------- leases
def test_lease_pins_generation_through_gc(tmp_path=None):
    store, docs1, _docs2, c1, c2 = _fixture(n1=120, n2=120)
    idx = Index.build(c1, CFG, store, "index/lease")
    w = idx.writer()
    w.append(c2)
    w.commit()                                   # gen 2: base + segment
    reg = LeaseRegistry()
    lease = reg.acquire("index/lease", 2)
    pinned = Index.open(store, "index/lease", generation=2).searcher()
    expect = pinned.query_batch(MIXED)

    w.merge()                                    # gen 3: fresh base only
    # grace 0 + keep 1 would normally delete gen<=2 outright; the lease
    # must keep every blob generation 2 reaches — no warning either
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        report = collect_garbage(store, "index/lease", keep=1,
                                 grace_s=0.0, leases=reg)
    gen2_live = reachable_blobs(store, "index/lease", keep=1,
                                min_generation=2)
    assert not (set(report.deleted) & gen2_live)
    again = Index.open(store, "index/lease", generation=2).searcher()
    assert _identical(expect, again.query_batch(MIXED))

    lease.release()
    lease.release()                              # idempotent
    assert reg.min_generation("index/lease") is None
    collect_garbage(store, "index/lease", keep=1, grace_s=0.0, leases=reg)
    with pytest.raises(Exception):
        Index.open(store, "index/lease", generation=2)


def test_service_leases_move_with_refresh(tmp_path=None):
    store, _docs1, _docs2, c1, c2 = _fixture(n1=120, n2=120)
    idx = Index.build(c1, CFG, store, "index/svclease")
    reg = LeaseRegistry()
    svc = SearchService(Index.open(store, "index/svclease"), leases=reg)
    assert reg.min_generation("index/svclease") == 1
    w = idx.writer()
    w.append(c2)
    w.commit()
    assert reg.min_generation("index/svclease") == 1   # not yet swapped
    assert svc.refresh() is True
    assert reg.min_generation("index/svclease") == 2   # moved atomically
    svc.close()
    assert reg.min_generation("index/svclease") is None


def test_cluster_gc_respects_service_leases(tmp_path=None):
    store, _docs1, _docs2, c1, c2 = _fixture(n1=400, n2=300)
    cluster = ShardedIndex.build(c1, CFG, store, "cluster/lease",
                                 n_shards=2)
    reg = LeaseRegistry()
    svc = SearchService(ShardedIndex.open(store, "cluster/lease"),
                        leases=reg, cache_size=16)
    expect = svc.search_batch(MIXED)
    # age every shard: append + merge makes the old bases unreachable
    # from latest-1 — only the service's leases protect them
    for s in range(cluster.n_shards):
        w = cluster.shard(s).writer()
        w.append(cluster.partition(c2)[s])
        w.commit()
        w.merge()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        collect_cluster_garbage(store, "cluster/lease", keep=1,
                                grace_s=0.0, leases=reg)
    assert _identical(expect, svc.search_batch(MIXED))  # snapshot intact
    assert svc.refresh() is True
    collect_cluster_garbage(store, "cluster/lease", keep=1, grace_s=0.0,
                            leases=reg)
    svc.close()


def test_grace_zero_without_registry_raises(monkeypatch):
    store, _docs1, _docs2, c1, _c2 = _fixture(n1=40, n2=20)
    Index.build(c1, CFG, store, "index/warn")
    with pytest.raises(UngracedSweepError, match="LeaseRegistry"):
        collect_garbage(store, "index/warn", keep=1, grace_s=0.0)
    with pytest.raises(UngracedSweepError, match="LeaseRegistry"):
        collect_cluster_garbage(store, "index/warn", keep=1, grace_s=0.0)
    # the typed error is still catchable as the old ValueError family
    assert issubclass(UngracedSweepError, ValueError)
    # compat flag restores the old warn-and-sweep behaviour
    monkeypatch.setenv("REPRO_ALLOW_DEPRECATED", "1")
    with pytest.warns(DeprecationWarning, match="LeaseRegistry"):
        collect_garbage(store, "index/warn", keep=1, grace_s=0.0)
    with pytest.warns(DeprecationWarning, match="LeaseRegistry"):
        collect_cluster_garbage(store, "index/warn", keep=1, grace_s=0.0)
    monkeypatch.delenv("REPRO_ALLOW_DEPRECATED")
    # either protection silences it
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        collect_garbage(store, "index/warn", keep=1, grace_s=600.0)
        collect_garbage(store, "index/warn", keep=1, grace_s=0.0,
                        leases=LeaseRegistry())


# -------------------------------------------- property: random interleavings
@settings(max_examples=10, deadline=None)
@given(st.data())
def test_interleaved_ops_keep_leases_safe_and_docs_visible(data):
    """Random add/commit/refresh/merge/gc interleavings with a leased
    reader: (1) the leased generation's blobs are never deleted — the
    pinned searcher keeps answering exactly; (2) every document whose
    add/commit notification was observed (bus drained) is visible to
    the following service — no lost update between notify and swap."""
    store = InMemoryBlobStore()
    docs = make_logs_like(30, seed=5)
    base = write_corpus(store, "corpus/prop", docs, n_blobs=1)
    cfg = BuilderConfig(B=600, F0=1.0, index_ngrams=0)
    idx = Index.build(base, cfg, store, "index/prop")
    bus = GenerationBus()
    idx.attach_bus(bus)
    reg = LeaseRegistry()
    svc = SearchService(idx, leases=reg).follow(bus)
    pin = reg.acquire("index/prop", 1)           # an unmoving old reader
    pinned = Index.open(store, "index/prop", generation=1).searcher()
    baseline = pinned.query("error")
    w = idx.writer()
    sentinels: list[str] = []

    n_ops = data.draw(st.integers(min_value=2, max_value=7))
    for step in range(n_ops):
        op = data.draw(st.sampled_from(
            ["add", "commit", "refresh", "merge", "gc"]))
        if op == "add":
            k = len(sentinels)
            word = f"sentineldoc{k}"
            extra = write_corpus(store, f"corpus/prop-x{k}",
                                 [f"{word} payload entry"], n_blobs=1)
            w.add(extra)
            sentinels.append(word)
        elif op == "commit":
            if w.n_staged:
                w.commit()
        elif op == "refresh":
            svc.refresh()
        elif op == "merge":
            if not w.n_staged:
                w.merge()
        elif op == "gc":
            collect_garbage(store, "index/prop", keep=1, grace_s=0.0,
                            leases=reg)
        bus.drain()     # observe whatever notifications the op posted
        # invariant 2: every added-and-notified doc is visible NOW
        for word in sentinels:
            res = svc.search(word)
            assert len(res.texts) == 1 and word in res.texts[0], \
                f"step {step} op {op}: lost {word}"
        # invariant 1: the leased generation still answers, unchanged
        res = pinned.query("error")
        assert res.texts == baseline.texts and res.refs == baseline.refs
    assert pin.generation == 1   # lease held throughout
