"""Builder + Searcher end-to-end against brute-force ground truth,
including baselines, boolean queries, top-K, hedging, and the paper's
expected-false-positive validation."""

import numpy as np
import pytest

from repro.core.analysis import CorpusProfile, F_exact
from repro.data import make_logs_like, make_zipf, write_corpus
from repro.data.tokenizer import distinct_words
from repro.index import And, Builder, BuilderConfig, Or, Searcher, Term
from repro.index.baselines import BTreeIndex, SkipListIndex
from repro.storage import (InMemoryBlobStore, SimCloudStore,
                           SimCloudTransport)


@pytest.fixture(scope="module")
def built():
    store = InMemoryBlobStore()
    docs = make_logs_like(3000, seed=1)
    corpus = write_corpus(store, "corpus/logs", docs, n_blobs=3)
    report = Builder(BuilderConfig(B=1500, F0=1.0, hedge_layers=1)).build(
        corpus, store, "index/logs")
    truth: dict[str, set[int]] = {}
    for i, d in enumerate(docs):
        for w in distinct_words(d):
            truth.setdefault(w, set()).add(i)
    return store, docs, report, truth


def test_build_report_sane(built):
    _store, docs, report, truth = built
    assert report.n_docs == len(docs)
    assert report.n_terms == len(truth)
    assert 1 <= report.L <= 8
    assert report.L_total == report.L + 1
    assert report.expected_fp <= 1.0
    assert report.n_common == 15          # 1% of B
    assert report.index_bytes > 0


def test_queries_exact_after_filtering(built):
    store, docs, _report, truth = built
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=3)), "index/logs")
    rng = np.random.default_rng(0)
    words = rng.choice(sorted(truth), size=60, replace=False)
    for w in words:
        res = s.query(str(w))
        assert set(res.texts) == {docs[i] for i in truth[str(w)]}, w
        assert res.stats.rounds <= 2          # the single-round-trip story


def test_zero_result_query(built):
    store, _docs, _report, _truth = built
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=3)), "index/logs")
    res = s.query("zzz-not-a-word-zzz")
    assert res.texts == [] and res.stats.n_results == 0


def test_boolean_queries(built):
    store, docs, _report, truth = built
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=3)), "index/logs")
    words = sorted(truth, key=lambda w: -len(truth[w]))[20:24]
    a, b, c = words[0], words[1], words[2]
    r = s.query(And((Term(a), Term(b))))
    assert set(r.texts) == {docs[i] for i in truth[a] & truth[b]}
    r = s.query(Or((Term(a), Term(c))))
    assert set(r.texts) == {docs[i] for i in truth[a] | truth[c]}
    r = s.query(Or((And((Term(a), Term(b))), Term(c))))
    assert set(r.texts) == {docs[i]
                            for i in (truth[a] & truth[b]) | truth[c]}


def test_topk(built):
    store, _docs, _report, truth = built
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=3)), "index/logs")
    w = max(truth, key=lambda w: len(truth[w]))
    res = s.query(w, top_k=5)
    assert len(res.texts) == 5
    assert all(w in distinct_words(t) for t in res.texts)


def test_hedged_query_correct(built):
    store, docs, _report, truth = built
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=3)), "index/logs")
    some = sorted(truth)[100]
    res = s.query(some, hedge=True)
    assert set(res.texts) == {docs[i] for i in truth[some]}


def test_observed_fp_within_hoeffding_of_expectation(built):
    """Fig. 5 / Eq. 5: measured false positives concentrate around F(L)."""
    store, _docs, report, truth = built
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=3)), "index/logs")
    rng = np.random.default_rng(1)
    rare = [w for w in truth if len(truth[w]) <= 3]
    words = rng.choice(rare, size=min(80, len(rare)), replace=False)
    fps = [s.query(str(w)).stats.n_false_positives for w in words]
    assert np.mean(fps) <= report.expected_fp + 3 * report.sigma_x + 0.5


def test_baselines_same_results_slower_lookup(built):
    store, docs, _report, truth = built
    for cls, prefix in ((BTreeIndex, "index/bt"), (SkipListIndex, "index/sl")):
        idx = cls(store, prefix)
        idx.build(_corpus_of(store, docs))
        bs = idx.open(SimCloudStore(store, seed=3))
        w = sorted(truth)[50]
        r = bs.query(w)
        assert set(r.texts) == {docs[i] for i in truth[w]}
        assert r.stats.rounds >= 3       # root→…→leaf→postings→docs
        s = Searcher(SimCloudTransport(SimCloudStore(store, seed=3)), "index/logs")
        ra = s.query(w)
        assert ra.stats.lookup.elapsed_s < r.stats.lookup.elapsed_s


def _corpus_of(store, docs):
    from repro.data.corpus import Corpus, DocRef
    # rebuild refs from the stored blobs (same layout as fixture)
    from repro.data import write_corpus
    return write_corpus(store, "corpus/logs", docs, n_blobs=3)


def test_manual_L_override_and_hashtable_equivalence():
    """L=1 manual config == the paper's HashTable baseline definition."""
    store = InMemoryBlobStore()
    docs = make_zipf(500, 300, 12, seed=2)
    corpus = write_corpus(store, "corpus/z", docs, n_blobs=2)
    r1 = Builder(BuilderConfig(B=300, L=1)).build(corpus, store, "index/h1")
    s = Searcher(SimCloudTransport(SimCloudStore(store, seed=0)), "index/h1")
    assert s.L == 1
    truth: dict[str, set[int]] = {}
    for i, d in enumerate(docs):
        for w in distinct_words(d):
            truth.setdefault(w, set()).add(i)
    w = sorted(truth)[10]
    res = s.query(w)
    assert set(res.texts) == {docs[i] for i in truth[w]}
    assert r1.L == 1


def test_multilayer_beats_hashtable_on_false_positives():
    """Fig. 5's core observation: L>1 slashes false positives at fixed B."""
    store = InMemoryBlobStore()
    docs = make_zipf(800, 400, 12, seed=3)
    corpus = write_corpus(store, "corpus/z2", docs, n_blobs=2)
    fps = {}
    for L in (1, 3):
        Builder(BuilderConfig(B=240, L=L, common_frac=0.0)).build(
            corpus, store, f"index/L{L}")
        s = Searcher(SimCloudTransport(SimCloudStore(store, seed=0)), f"index/L{L}")
        rng = np.random.default_rng(0)
        truth: dict[str, set[int]] = {}
        for i, d in enumerate(docs):
            for w in distinct_words(d):
                truth.setdefault(w, set()).add(i)
        words = rng.choice(sorted(truth), 40, replace=False)
        fps[L] = np.mean([s.query(str(w)).stats.n_false_positives
                          for w in words])
    assert fps[3] < 0.5 * fps[1] or fps[3] < 0.5
