import os
import sys

# tests must see ONE device (the dry-run alone uses 512 placeholders)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# the whole suite runs with the lock-order detector armed: any lock-order
# inversion anywhere fails fast with the cycle instead of a hang
os.environ.setdefault("REPRO_LOCK_CHECK", "1")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
