"""Documentation stays navigable: the README and every doc under docs/
exist, their internal links and anchors resolve (scripts/check_docs.py,
the same checker the docs CI job runs), and the README's verify command
matches the ROADMAP's tier-1 command."""

import os
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "scripts"))

import check_docs  # noqa: E402


def test_readme_and_docs_exist():
    assert os.path.exists(os.path.join(REPO, "README.md"))
    expected = {"architecture.md", "index_lifecycle.md",
                "query_engine.md", "query_language.md",
                "serving_cluster.md"}
    have = set(os.listdir(os.path.join(REPO, "docs")))
    assert expected <= have, expected - have


def test_internal_links_resolve():
    errors = check_docs.run(repo_root=REPO)
    assert errors == [], "\n".join(errors)


def test_readme_carries_the_tier1_command():
    with open(os.path.join(REPO, "README.md"), encoding="utf-8") as f:
        readme = f.read()
    assert "PYTHONPATH=src python -m pytest -x -q" in readme


def test_slug_rules_match_github():
    # the anchors other docs rely on (architecture.md cross-links)
    assert check_docs.github_slug("Resharding & GC") == "resharding--gc"
    assert check_docs.github_slug("The StorageTransport protocol") == \
        "the-storagetransport-protocol"
    assert check_docs.github_slug("`code` and *emph*") == "code-and-emph"
