"""IoU Sketch core invariants: hashing, no-false-negatives, accuracy model,
Algorithm 1, top-K (property-based where it matters)."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (CorpusProfile, F_approx, F_exact, HashFamily,
                        InfeasibleSketchError, IoUSketch, L_star_per_doc,
                        SketchSpec, fast_region_bound,
                        feasibility_lower_bound, hoeffding_epsilon,
                        minimize_layers, q_approx, q_exact, sample_size,
                        sigma_x, word_fingerprint)


# ------------------------------------------------------------------- hashing
def test_hash_deterministic_and_ranged():
    fam = HashFamily.make(4, 97, seed=3)
    words = [f"word{i}" for i in range(500)]
    keys = np.array([word_fingerprint(w) for w in words], dtype=np.uint64)
    b1 = fam.bins(keys)
    b2 = fam.bins(keys)
    assert (b1 == b2).all()
    assert b1.shape == (4, 500)
    assert b1.min() >= 0 and b1.max() < 97


def test_hash_layers_differ():
    fam = HashFamily.make(3, 1000, seed=0)
    keys = np.arange(1, 2000, dtype=np.uint64)
    bins = fam.bins(keys)
    # different layers produce (nearly) independent mappings
    assert (bins[0] != bins[1]).mean() > 0.9
    assert (bins[1] != bins[2]).mean() > 0.9


def test_hash_roundtrip_serialization():
    fam = HashFamily.make(5, 123, seed=9)
    fam2 = HashFamily.from_dict(fam.to_dict())
    keys = np.arange(100, dtype=np.uint64)
    assert (fam.bins(keys) == fam2.bins(keys)).all()


def test_hash_uniformity():
    fam = HashFamily.make(1, 64, seed=1)
    keys = np.array([word_fingerprint(f"w{i}") for i in range(64_00)],
                    dtype=np.uint64)
    counts = np.bincount(fam.bins(keys)[0], minlength=64)
    # chi-square-ish: every bin within 3x of expectation
    assert counts.min() > 100 / 3 and counts.max() < 100 * 3


# ------------------------------------------- sketch: no false negatives, ever
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_sketch_no_false_negatives(data):
    n_words = data.draw(st.integers(5, 60))
    n_docs = data.draw(st.integers(5, 200))
    B = data.draw(st.integers(4, 64))
    L = data.draw(st.integers(1, 4))
    seed = data.draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    postings = {}
    for j in range(n_words):
        docs = rng.integers(0, n_docs, size=rng.integers(1, 20))
        postings[f"w{j}"] = np.unique(docs).astype(np.uint32)
    sketch = IoUSketch.build(postings, SketchSpec(B=B, L=L, seed=seed))
    for w, truth in postings.items():
        got = sketch.query(w)
        assert set(truth.tolist()) <= set(got.tolist()), \
            f"false negative for {w}"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**16))
def test_sketch_hedged_query_is_superset(seed):
    rng = np.random.default_rng(seed)
    postings = {f"w{j}": np.unique(rng.integers(0, 100, 8)).astype(np.uint32)
                for j in range(40)}
    sketch = IoUSketch.build(postings, SketchSpec(B=60, L=3, seed=seed))
    for w in list(postings)[:10]:
        full = set(sketch.query(w).tolist())
        hedged = set(sketch.query(w, wait_for=2).tolist())
        assert full <= hedged          # fewer layers => more candidates
        assert set(postings[w].tolist()) <= hedged


def test_common_words_exact():
    rng = np.random.default_rng(0)
    postings = {f"w{j}": np.unique(rng.integers(0, 50, 5)).astype(np.uint32)
                for j in range(30)}
    postings["the"] = np.arange(50, dtype=np.uint32)   # very common
    sketch = IoUSketch.build(postings, SketchSpec(B=16, L=2, n_common=1),
                             common_words=["the"])
    assert sketch.is_common("the")
    assert (sketch.query("the") == postings["the"]).all()


# ----------------------------------------------------------- accuracy model
def test_q_exact_matches_empirical_collision_rate():
    """Eq. 1 against a Monte-Carlo of the real hashing process."""
    B, L, Wi = 64, 2, 30
    trials = 400
    rng = np.random.default_rng(0)
    hits = 0
    for t in range(trials):
        fam = HashFamily.make(L, B // L, seed=t)
        doc_words = np.asarray(
            [hash(f"d{t}w{i}") & 0xFFFFFFFFFFFF for i in range(Wi)],
            dtype=np.uint64)
        probe = np.asarray([hash(f"probe{t}") & 0xFFFFFFFFFFFF],
                           dtype=np.uint64)
        doc_bins = fam.bins(doc_words)
        probe_bins = fam.bins(probe)[:, 0]
        collided = all(probe_bins[l] in set(doc_bins[l].tolist())
                       for l in range(L))
        hits += collided
    q = q_exact(np.array([Wi]), L, B)[0]
    se = math.sqrt(q * (1 - q) / trials)
    assert abs(hits / trials - q) < max(4 * se, 0.05)


def test_q_approx_close_to_exact():
    sizes = np.array([5, 20, 80, 300])
    for L in (1, 2, 4):
        qe = q_exact(sizes, L, 1000)
        qa = q_approx(sizes, L, 1000)
        np.testing.assert_allclose(qa, qe, rtol=0.15, atol=1e-4)


def test_lemma1_minimizer():
    """L_i* = (B/|W_i|) ln 2 minimizes q̂_i over a fine grid."""
    B, Wi = 1000, 40
    li = L_star_per_doc(np.array([Wi]), B)[0]
    grid = np.linspace(max(li - 10, 1), li + 10, 400)
    vals = [q_approx(np.array([Wi]), L, B)[0] for L in grid]
    assert abs(grid[int(np.argmin(vals))] - li) < 0.2
    # and q̂(L*) = 2^{-L*}
    assert q_approx(np.array([Wi]), li, B)[0] == pytest.approx(
        2.0 ** -li, rel=1e-6)


def test_lemma2_lemma3_monotonicity():
    sizes = np.array([10, 25, 50])
    profile = CorpusProfile.from_doc_sizes(sizes, n_terms=100)
    B = 400
    lmin, lmax = fast_region_bound(profile, B)
    grid_lo = np.linspace(1, lmin, 20)
    vals_lo = [F_approx(profile, L, B) for L in grid_lo]
    assert all(a > b for a, b in zip(vals_lo, vals_lo[1:]))   # decreasing
    grid_hi = np.linspace(lmax, min(2 * lmax, B), 20)
    vals_hi = [F_approx(profile, L, B) for L in grid_hi]
    assert all(a < b for a, b in zip(vals_hi, vals_hi[1:]))   # increasing


def test_feasibility_lower_bound_is_lower_bound():
    profile = CorpusProfile.from_doc_sizes(
        np.array([10, 30, 90, 200]), n_terms=500)
    B = 800
    lb = feasibility_lower_bound(profile, B)
    for L in range(1, 60):
        assert F_exact(profile, L, B) >= lb * 0.999


# -------------------------------------------------------------- Algorithm 1
@settings(max_examples=20, deadline=None)
@given(st.data())
def test_algorithm1_minimality(data):
    """L* is feasible and L*-1 is not (within the searched region)."""
    n_docs = data.draw(st.integers(10, 150))
    seed = data.draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    sizes = rng.integers(3, 60, size=n_docs)
    profile = CorpusProfile.from_doc_sizes(sizes, n_terms=int(sizes.sum()))
    B = data.draw(st.integers(100, 3000))
    F0 = data.draw(st.floats(0.05, 10.0))
    try:
        choice = minimize_layers(profile, B, F0)
    except InfeasibleSketchError:
        # rejection must be justified: brute-force check a range of L
        for L in range(1, min(B, 200)):
            assert F_exact(profile, L, B) > F0
        return
    assert F_exact(profile, choice.L, B) <= F0
    if choice.L > 1 and choice.region == "fast":
        assert F_exact(profile, choice.L - 1, B) > F0


def test_algorithm1_matches_brute_force():
    rng = np.random.default_rng(5)
    sizes = rng.integers(5, 50, size=80)
    profile = CorpusProfile.from_doc_sizes(sizes, n_terms=int(sizes.sum()))
    B = 500
    for F0 in (5.0, 1.0, 0.2, 0.01):
        brute = next((L for L in range(1, B)
                      if F_exact(profile, L, B) <= F0), None)
        try:
            choice = minimize_layers(profile, B, F0)
            assert brute is not None
            assert choice.L == brute, (choice.L, brute, F0)
        except InfeasibleSketchError:
            assert brute is None or brute > fast_region_bound(profile, B)[1]


# -------------------------------------------------------------------- top-K
def test_topk_paper_default_is_23():
    """K=10, F0=1, δ=1e-6 selects ~23 samples (paper §V-A0c)."""
    assert sample_size(1000, 10, 1.0, 1e-6) == 23


def test_topk_fetches_all_when_small():
    assert sample_size(5, 10, 1.0) == 5
    assert sample_size(11, 10, 1.0) == 11     # K >= R - F0


@settings(max_examples=15, deadline=None)
@given(st.integers(30, 5000), st.integers(1, 20), st.floats(0.0, 3.0))
def test_topk_monotone_and_bounded(R, K, F0):
    rk = sample_size(R, K, F0)
    assert K <= rk <= R or K >= R - F0
    assert sample_size(R, K, F0, 1e-9) >= sample_size(R, K, F0, 1e-3)


def test_topk_statistical_guarantee():
    """Sampling R_K candidates yields >= K relevant w.h.p."""
    rng = np.random.default_rng(0)
    R, K, F0, delta = 200, 10, 1.0, 1e-6
    rk = sample_size(R, K, F0, delta)
    failures = 0
    for _ in range(300):
        relevant = np.ones(R, bool)
        fp = rng.integers(0, R, size=rng.poisson(F0))
        relevant[fp] = False
        sample = rng.choice(R, size=rk, replace=False)
        if relevant[sample].sum() < K:
            failures += 1
    assert failures == 0


# ---------------------------------------------------------------- sigma_X
def test_sigma_x_matches_table2_formula():
    """Cranfield row of Table II: n=1398, |W|=5300, avg |W_i|≈86 → 0.51."""
    rng = np.random.default_rng(0)
    sizes = np.clip(rng.normal(86, 20, size=1398), 10, 300).astype(int)
    profile = CorpusProfile.from_doc_sizes(sizes, n_terms=5300)
    assert sigma_x(profile) == pytest.approx(0.51, abs=0.02)


def test_hoeffding_epsilon_positive_and_scales():
    profile = CorpusProfile.from_doc_sizes(np.array([10] * 100), n_terms=1000)
    e1 = hoeffding_epsilon(profile, 1e-3)
    e2 = hoeffding_epsilon(profile, 1e-9)
    assert 0 < e1 < e2
