"""Optimizer, gradient compression, checkpointing, fault-tolerant loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.storage import InMemoryBlobStore, SimCloudStore
from repro.training import (CheckpointConfig, CheckpointManager,
                            OptimizerConfig, adamw_update, global_norm,
                            init_opt_state, schedule_lr)
from repro.training.grad_compress import (bf16_compress, ef_compress_step,
                                          init_residual, int8_compress,
                                          int8_decompress)


# ---------------------------------------------------------------- optimizer
def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(0, 1, (4, 3)), jnp.float32),
         "b": jnp.asarray(rng.normal(0, 1, (3,)), jnp.float32)}
    g = jax.tree.map(lambda x: jnp.ones_like(x) * 0.1, p)
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                          schedule="constant", clip_norm=0.0,
                          weight_decay=0.5)
    opt = init_opt_state(p)
    new_p, new_opt, metrics = adamw_update(p, g, opt, cfg)
    # numpy replay
    m = 0.1 * 0.1
    v = 0.05 * 0.1 ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    w_np = np.asarray(p["w"])
    want_w = w_np - 1e-2 * (mhat / (np.sqrt(vhat) + 1e-8) + 0.5 * w_np)
    want_b = np.asarray(p["b"]) - 1e-2 * (mhat / (np.sqrt(vhat) + 1e-8))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want_w, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_p["b"]), want_b, rtol=1e-5)
    assert int(new_opt["step"]) == 1


def test_grad_clipping():
    p = {"w": jnp.zeros((10,), jnp.float32)}
    g = {"w": jnp.full((10,), 100.0)}
    cfg = OptimizerConfig(lr=1.0, clip_norm=1.0, warmup_steps=0,
                          schedule="constant", weight_decay=0.0)
    opt = init_opt_state(p)
    _, _, metrics = adamw_update(p, g, opt, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(
        np.sqrt(10) * 100, rel=1e-4)


def test_schedule_shapes():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          schedule="cosine", min_lr_frac=0.1)
    lrs = [float(schedule_lr(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1.0, rel=1e-3)
    assert lrs[100] == pytest.approx(0.1, rel=1e-2)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decays


# --------------------------------------------------------------- compression
def test_int8_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
    q, s = int8_compress(g)
    back = int8_decompress(q, s)
    err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
    assert err <= float(s["w"]) * 0.51


def test_error_feedback_converges():
    """EF compensates quantization bias: averaged decompressed grads
    converge to the true mean gradient."""
    rng = np.random.default_rng(0)
    true = jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)
    residual = init_residual({"g": true})
    acc = jnp.zeros_like(true)
    n = 200
    for _ in range(n):
        decomp, residual = ef_compress_step({"g": true}, residual)
        acc = acc + decomp["g"]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(true),
                               atol=0.01)


def test_bf16_compress_dtype():
    g = {"w": jnp.ones((4,), jnp.float32)}
    assert bf16_compress(g)["w"].dtype == jnp.bfloat16


# --------------------------------------------------------------- checkpoints
def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.bfloat16),
                   "b": jnp.asarray(rng.normal(0, 1, (4,)), jnp.float32)},
        "opt": {"step": jnp.int32(7)},
    }


def test_checkpoint_roundtrip_bf16():
    store = InMemoryBlobStore()
    ckpt = CheckpointManager(store)
    state = _state()
    ckpt.save(7, state)
    restored, manifest = ckpt.restore(state)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(
        np.asarray(state["params"]["w"], np.float32),
        np.asarray(restored["params"]["w"], np.float32))
    assert restored["params"]["w"].dtype == np.asarray(
        state["params"]["w"]).dtype
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_keep_last_k():
    store = InMemoryBlobStore()
    ckpt = CheckpointManager(store, CheckpointConfig(keep_last_k=2))
    for step in (1, 2, 3, 4):
        ckpt.save(step, _state())
    assert ckpt.all_steps() == [3, 4]


def test_checkpoint_corruption_detected():
    store = InMemoryBlobStore()
    ckpt = CheckpointManager(store)
    ckpt.save(1, _state())
    # flip bytes in one leaf blob
    name = [n for n in store.list() if n.endswith("w.npy")][0]
    store.put(name, b"\x00" * store.size(name))
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(_state())


def test_checkpoint_async_save_and_latest():
    store = InMemoryBlobStore()
    ckpt = CheckpointManager(store)
    ckpt.save(5, _state(), blocking=False)
    ckpt.wait()
    assert ckpt.latest_step() == 5


def test_checkpoint_restore_via_simcloud_single_round():
    store = InMemoryBlobStore()
    ckpt = CheckpointManager(store)
    ckpt.save(3, _state())
    cloud = SimCloudStore(store, seed=0)
    before = cloud.totals.n_requests
    restored, _ = ckpt.restore(_state(), cloud=cloud)
    # all leaves fetched in ONE parallel batch
    assert cloud.totals.n_requests - before == len(
        jax.tree.leaves(_state()))
    assert cloud.clock_s < 0.2


# --------------------------------------------------------------- train loop
def test_train_loop_loss_decreases_and_resumes():
    from repro.configs import get_config
    from repro.data import make_logs_like, write_corpus
    from repro.data.pipeline import IndexedCorpusLoader, PipelineConfig
    from repro.index import Builder, BuilderConfig
    from repro.models import NULL_RULES, build_model, init_params
    from repro.training.train_loop import TrainLoopConfig, run

    store = InMemoryBlobStore()
    docs = make_logs_like(500, seed=2)
    from repro.data import write_corpus as wc
    corpus = wc(store, "corpus/t", docs, n_blobs=2)
    Builder(BuilderConfig(B=500, F0=1.0)).build(corpus, store, "index/t")
    cloud = SimCloudStore(store, seed=0)
    cfg = get_config("granite-20b", reduced=True).with_(
        n_layers=2, d_model=64, n_heads=2, n_kv=1, d_ff=128, vocab=256)
    loader = IndexedCorpusLoader(
        cloud, "index/t",
        PipelineConfig(seq_len=32, batch_size=4, vocab_size=cfg.vocab))
    model = build_model(cfg)
    params = init_params(model.param_desc(), jax.random.PRNGKey(0))
    ckpt = CheckpointManager(store, CheckpointConfig(prefix="ck"))
    opt_cfg = OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=30)
    loop_cfg = TrainLoopConfig(total_steps=30, checkpoint_every=10,
                               log_every=5, async_checkpoint=False)
    state, log = run(model, params, loader, ckpt, loop_cfg, opt_cfg,
                     NULL_RULES)
    assert log.losses[-1] < log.losses[0]          # it learns
    assert ckpt.latest_step() == 30

    # fault tolerance: "crash" and restart — resumes from step 30 (no-op)
    params2 = init_params(model.param_desc(), jax.random.PRNGKey(0))
    state2, log2 = run(model, params2, loader, ckpt, loop_cfg, opt_cfg,
                       NULL_RULES)
    assert log2.resumed_from == 30
    np.testing.assert_array_equal(
        np.asarray(state["params"]["lm_head"], np.float32),
        np.asarray(state2["params"]["lm_head"], np.float32))

    # and a restart from a mid-run checkpoint continues deterministically
    loop3 = TrainLoopConfig(total_steps=40, checkpoint_every=10,
                            log_every=5, async_checkpoint=False)
    state3, log3 = run(model, params2, loader, ckpt, loop3, opt_cfg,
                       NULL_RULES)
    assert log3.resumed_from == 30
    assert int(state3["opt"]["step"]) == 40
