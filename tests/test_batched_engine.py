"""Batched query engine: cross-query fetch planning, range coalescing,
superpost caching, and the batched Pallas intersection kernel.

The load-bearing invariant everywhere: batching/coalescing/caching may
only change *when bytes move*, never *which bytes* a query sees — every
optimized path must be result-identical to the serial seed engine."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.compat import DeprecatedAPIError
from repro.core.sketch import intersect_sorted
from repro.data import make_logs_like, write_corpus
from repro.data.tokenizer import distinct_words
from repro.index import (And, Builder, BuilderConfig, Or, Regex, Searcher,
                         Term, coalesce_requests, slice_payloads)
from repro.kernels.intersect import (intersect, intersect_batch,
                                     postings_to_bitmap,
                                     postings_to_bitmap_batch)
from repro.serving import SearchService
from repro.storage import (InMemoryBlobStore, LRUCache, RangeRequest,
                           SimCloudStore, SimCloudTransport,
                           SuperpostCache)


# ------------------------------------------------------------- coalescing
def test_coalesce_merges_adjacent_and_overlapping():
    reqs = [RangeRequest("b", 0, 10), RangeRequest("b", 10, 10),
            RangeRequest("b", 15, 10), RangeRequest("b", 100, 5)]
    merged, slices = coalesce_requests(reqs, gap=0)
    assert [(m.blob, m.offset, m.length) for m in merged] == \
        [("b", 0, 25), ("b", 100, 5)]
    assert slices == [(0, 0), (0, 10), (0, 15), (1, 0)]


def test_coalesce_gap_and_blob_isolation():
    reqs = [RangeRequest("a", 0, 10), RangeRequest("a", 30, 10),
            RangeRequest("b", 12, 4)]
    merged0, _ = coalesce_requests(reqs, gap=0)
    assert len(merged0) == 3                       # gap 20 > 0: no merge
    merged, slices = coalesce_requests(reqs, gap=20)
    assert [(m.blob, m.offset, m.length) for m in merged] == \
        [("a", 0, 40), ("b", 12, 4)]
    assert slices[1] == (0, 30)


def test_coalesce_slices_recover_exact_payloads():
    rng = np.random.default_rng(0)
    data = bytes(rng.integers(0, 256, 4096, dtype=np.uint8))
    store = InMemoryBlobStore()
    store.put("blob", data)
    reqs = [RangeRequest("blob", int(o), int(n))
            for o, n in zip(rng.integers(0, 3800, 40),
                            rng.integers(1, 200, 40))]
    merged, slices = coalesce_requests(reqs, gap=64)
    merged_payloads = [store.get_range(m) for m in merged]
    out = slice_payloads(reqs, merged_payloads, slices)
    for req, payload in zip(reqs, out):
        assert payload == store.get_range(req)
    assert len(merged) < len(reqs)


def test_coalesce_passes_unbounded_through():
    reqs = [RangeRequest("b"), RangeRequest("b", 0, 8)]
    merged, slices = coalesce_requests(reqs, gap=1 << 30)
    assert len(merged) == 2 and merged[slices[0][0]].length == -1


# ---------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def engine():
    store = InMemoryBlobStore()
    docs = make_logs_like(2500, seed=11)
    corpus = write_corpus(store, "corpus/be", docs, n_blobs=3)
    Builder(BuilderConfig(B=1500, F0=1.0, index_ngrams=3,
                          hedge_layers=1)).build(corpus, store, "index/be")
    truth: dict[str, set[int]] = {}
    for i, d in enumerate(docs):
        for w in distinct_words(d):
            truth.setdefault(w, set()).add(i)
    return store, docs, truth


MIXED = [
    "error", "info", "block",                       # plain/common terms
    And((Term("error"), Term("node42"))),
    And((Term("info"), Term("block"), Term("from"))),
    Or((Term("warn"), Term("node7"))),
    Or((And((Term("error"), Term("block"))), Term("node9"))),
    Regex(r"blk_4[0-9]1\b"),
]


# --------------------------------------------- batched == serial, bytewise
def test_lookup_batch_identical_to_per_query_lookup(engine):
    store, _docs, truth = engine
    serial = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)), "index/be",
                      coalesce_gap=None)                # seed engine
    batched = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)), "index/be")
    queries = [And((Term("error"), Term("block"))), Term("info"),
               Term("error"), Or((Term("node4"), Term("error")))]
    outs, _stats = batched.lookup_batch(queries)
    for q, per_word in zip(queries, outs):
        ref, _ = serial.lookup(q)
        assert set(per_word) == set(ref)
        for w in ref:
            np.testing.assert_array_equal(per_word[w][0], ref[w][0])
            np.testing.assert_array_equal(per_word[w][1], ref[w][1])


def test_query_batch_identical_to_serial(engine):
    store, docs, truth = engine
    serial = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)), "index/be",
                      coalesce_gap=None)
    expect = [serial.regex_query(q.pattern) if isinstance(q, Regex)
              else serial.query(q) for q in MIXED]
    batched = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)), "index/be")
    got = batched.query_batch(MIXED)
    for q, a, b in zip(MIXED, expect, got):
        assert a.texts == b.texts, q
        assert a.refs == b.refs, q
        assert a.stats.n_candidates == b.stats.n_candidates
        assert a.stats.n_false_positives == b.stats.n_false_positives
    # ground truth for one of them, for good measure
    r = got[3]
    assert set(r.texts) == {docs[i]
                            for i in truth["error"] & truth["node42"]}


def test_query_batch_topk_identical_to_serial(engine):
    store, _docs, truth = engine
    serial = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)), "index/be",
                      coalesce_gap=None)
    batched = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)), "index/be")
    queries = ["error", "info", "block", "node1"]
    expect = [serial.query(q, top_k=5) for q in queries]
    got = batched.query_batch(queries, top_k=5)
    for a, b in zip(expect, got):
        assert a.texts == b.texts
        assert a.refs == b.refs


def test_query_batch_fewer_requests_and_lower_clock(engine):
    store, _docs, _truth = engine
    serial_cloud = SimCloudStore(store, seed=5)
    serial = Searcher(SimCloudTransport(serial_cloud), "index/be", coalesce_gap=None)
    for q in MIXED:
        (serial.regex_query(q.pattern) if isinstance(q, Regex)
         else serial.query(q))
    batched_cloud = SimCloudStore(store, seed=5)
    Searcher(SimCloudTransport(batched_cloud), "index/be").query_batch(MIXED)
    assert batched_cloud.totals.n_requests < 0.7 * serial_cloud.totals.n_requests
    assert batched_cloud.clock_s < serial_cloud.clock_s


def test_query_batch_hedged_is_superset_and_batches(engine):
    store, docs, truth = engine
    batched = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)), "index/be")
    got = batched.query_batch(["error", "node3"], hedge=True)
    for q, res in zip(["error", "node3"], got):
        assert {docs[i] for i in truth[q]} == set(res.texts)


# -------------------------------------------------------- superpost cache
def test_superpost_cache_result_identical_fewer_requests(engine):
    store, _docs, _truth = engine
    plain_cloud = SimCloudStore(store, seed=5)
    plain = Searcher(SimCloudTransport(plain_cloud), "index/be")
    expect = [plain.query_batch(MIXED[:7]) for _ in range(3)]

    cached_cloud = SimCloudStore(store, seed=5)
    cached = Searcher(SimCloudTransport(cached_cloud), "index/be", cache=SuperpostCache(16 << 20))
    got = [cached.query_batch(MIXED[:7]) for _ in range(3)]
    for round_e, round_g in zip(expect, got):
        for a, b in zip(round_e, round_g):
            assert a.texts == b.texts and a.refs == b.refs
    assert cached_cloud.totals.n_requests < plain_cloud.totals.n_requests
    assert cached.cache.hits > 0
    assert cached.cache.bytes_saved > 0
    # hits are threaded into the per-round FetchStats
    assert got[1][0].stats.lookup.cache_hits > 0


def test_lru_cache_eviction_and_weighting():
    lru = LRUCache(3)
    for k in "abc":
        lru.put(k, k)
    lru.get("a")                        # refresh a
    lru.put("d", "d")                   # evicts b (LRU), not a (FIFO-head)
    assert "a" in lru and "b" not in lru and len(lru) == 3

    by_bytes = LRUCache(100, weigh=len)
    by_bytes.put("x", b"a" * 60)
    by_bytes.put("y", b"b" * 60)        # 120 > 100: x evicted
    assert "x" not in by_bytes and by_bytes.weight == 60
    by_bytes.put("huge", b"c" * 1000)   # heavier than the bound: rejected
    assert "huge" not in by_bytes


def test_search_service_result_cache_is_lru(engine):
    store, _docs, _truth = engine
    svc = SearchService(SimCloudTransport(SimCloudStore(store, seed=2)), "index/be",
                        cache_size=4)
    svc.search("error")
    for i in range(3):
        svc.search(f"node{i}")          # cache now full: error,node0,1,2
    svc.search("error")                 # hit — and refreshes recency
    assert svc.cache_hits == 1
    svc.search("node5")                 # evicts LRU entry node0, NOT error
    n = svc.stats.summary()["n"]
    svc.search("error")                 # still cached under LRU
    assert svc.cache_hits == 2
    assert svc.stats.summary()["n"] == n          # no new fetch observed
    assert svc.stats.summary()["cache_hit_rate"] > 0
    assert len(svc._cache) <= 4


# ------------------------------------------------------ service batch path
def test_service_search_batch_identical_and_faster(engine):
    store, _docs, _truth = engine
    serial_cloud = SimCloudStore(store, seed=9)
    serial_svc = SearchService(SimCloudTransport(serial_cloud), "index/be")
    expect = serial_svc.search_batch(MIXED, batched=False)

    batched_cloud = SimCloudStore(store, seed=9)
    batched_svc = SearchService(SimCloudTransport(batched_cloud), "index/be",
                                superpost_cache_bytes=16 << 20)
    got = batched_svc.search_batch(MIXED)
    for a, b in zip(expect, got):
        assert a.texts == b.texts and a.refs == b.refs
    assert batched_cloud.clock_s < serial_cloud.clock_s
    assert batched_cloud.totals.n_requests < serial_cloud.totals.n_requests


def test_service_search_batch_uses_result_cache(engine):
    store, _docs, _truth = engine
    svc = SearchService(SimCloudTransport(SimCloudStore(store, seed=9)), "index/be",
                        cache_size=16)
    r1 = svc.search_batch(["error", "info"])
    r2 = svc.search_batch(["error", "info"])
    assert svc.cache_hits == 2
    assert [r.texts for r in r1] == [r.texts for r in r2]


# ----------------------------------------------- batched intersect kernel
def _random_ragged_batch(rng, Q, n_docs):
    batch = []
    for _ in range(Q):
        L = int(rng.integers(1, 5))
        batch.append([np.unique(rng.integers(0, n_docs,
                                             int(rng.integers(1, n_docs))))
                      .astype(np.uint32) for _ in range(L)])
    return batch


@pytest.mark.parametrize("Q,n_docs", [(1, 100), (3, 4096), (5, 33_000)])
def test_intersect_batch_matches_single_and_oracle(Q, n_docs):
    rng = np.random.default_rng(Q * 7 + n_docs)
    batch = _random_ragged_batch(rng, Q, n_docs)
    bitmaps = postings_to_bitmap_batch(batch, n_docs)
    out_p, cnt_p = intersect_batch(bitmaps, impl="pallas")
    out_r, cnt_r = intersect_batch(bitmaps, impl="ref")
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(cnt_p), np.asarray(cnt_r))
    for q, posts in enumerate(batch):
        single, cnt_s = intersect(postings_to_bitmap(posts, n_docs),
                                  impl="pallas")
        np.testing.assert_array_equal(np.asarray(out_p)[q],
                                      np.asarray(single))
        oracle = intersect_sorted(posts)
        assert int(cnt_p[q]) == int(cnt_s) == len(oracle)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**16))
def test_intersect_batch_property_ragged(seed):
    rng = np.random.default_rng(seed)
    n_docs = int(rng.integers(32, 2000))
    batch = _random_ragged_batch(rng, int(rng.integers(1, 6)), n_docs)
    bitmaps = postings_to_bitmap_batch(batch, n_docs)
    out, counts = intersect_batch(bitmaps, impl="pallas")
    out = np.asarray(out)
    for q, posts in enumerate(batch):
        oracle = intersect_sorted(posts)
        bits = np.unpackbits(out[q].view(np.uint8), bitorder="little")
        got = np.flatnonzero(bits).astype(np.uint32)
        np.testing.assert_array_equal(got, oracle)
        assert int(counts[q]) == len(oracle)


def test_query_batch_bitmap_impl_identical(engine):
    store, _docs, _truth = engine
    sorted_res = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)),
                          "index/be").query_batch(MIXED)
    bitmap_res = Searcher(SimCloudTransport(SimCloudStore(store, seed=5)),
                          "index/be").query_batch(MIXED, impl="bitmap")
    for a, b in zip(sorted_res, bitmap_res):
        assert a.texts == b.texts and a.refs == b.refs


# ---------------------------------------------------------- O(1) exists
def test_blobstore_exists_direct(tmp_path):
    from repro.storage import LocalBlobStore
    mem = InMemoryBlobStore()
    mem.put("x/y", b"1")
    assert mem.exists("x/y") and not mem.exists("x/z")
    loc = LocalBlobStore(str(tmp_path))
    loc.put("a/b", b"1")
    assert loc.exists("a/b") and not loc.exists("a/c")
    # names that escape the root are rejected, same as get/put
    with pytest.raises(ValueError):
        loc.exists("../escape")


# --------------------------------------------- vectorized core fast paths
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**16))
def test_intersect_sorted_matches_sets(seed):
    rng = np.random.default_rng(seed)
    lists = [np.unique(rng.integers(0, 500, int(rng.integers(0, 300))))
             .astype(np.uint64) for _ in range(int(rng.integers(1, 5)))]
    got = intersect_sorted(lists)
    expect = set(lists[0].tolist())
    for l in lists[1:]:
        expect &= set(l.tolist())
    assert set(got.tolist()) == expect
    assert (np.diff(got.astype(np.int64)) > 0).all()  # sorted unique


# ------------------------------------------ serving-path bugfix regressions
def test_batched_latency_not_overcounted_vs_serial(engine):
    """A shared-round batch is ONE service event: recording its wall
    clock once per member used to make batched mean/p50/p99 incomparable
    with serial runs of the same workload."""
    store, _docs, _truth = engine
    serial_cloud = SimCloudStore(store, seed=31)
    serial_svc = SearchService(SimCloudTransport(serial_cloud), "index/be")
    serial_svc.search_batch(MIXED, batched=False)
    serial = serial_svc.stats.summary()

    batched_cloud = SimCloudStore(store, seed=31)
    batched_svc = SearchService(SimCloudTransport(batched_cloud), "index/be")
    t0 = batched_cloud.clock_s
    batched_svc.search_batch(MIXED)
    wall = batched_cloud.clock_s - t0
    batched = batched_svc.stats.summary()

    # both summaries account every query...
    assert serial["n_queries"] == batched["n_queries"] == len(MIXED)
    assert serial["n"] == len(MIXED) and batched["n"] == 1
    assert batched["mean_batch_size"] == len(MIXED)
    # ...but the batch contributes its wall clock ONCE, so the recorded
    # time equals the clock advance instead of ~N times it
    assert sum(batched_svc.stats.samples_s) == pytest.approx(wall)
    assert sum(batched_svc.stats.samples_s) < \
        sum(serial_svc.stats.samples_s)
    # and the sampled latencies stay comparable with serial samples
    assert batched["p99_ms"] < serial["p99_ms"] * len(MIXED)


def test_search_batch_dedupes_duplicate_queries(engine):
    """Duplicate queries in ONE cold batch (same normalized cache key)
    must be planned/fetched once, the result fanned back out."""
    store, _docs, _truth = engine
    once_cloud = SimCloudStore(store, seed=33)
    once = SearchService(SimCloudTransport(once_cloud), "index/be")
    once.search_batch(["error"])

    dup_cloud = SimCloudStore(store, seed=33)
    dup = SearchService(SimCloudTransport(dup_cloud), "index/be")
    # same key under normalization: a duplicate string AND a reordered
    # equivalent tree of it
    res = dup.search_batch(["error", Term("error"), "error"])
    assert dup_cloud.totals.n_requests == once_cloud.totals.n_requests
    assert res[0] is res[1] is res[2]
    assert dup.stats.summary()["n_queries"] == 1

    eq_cloud = SimCloudStore(store, seed=34)
    eq = SearchService(SimCloudTransport(eq_cloud), "index/be")
    tree = And((Term("error"), Term("block")))
    nested = And((Term("error"), And((Term("block"), Term("error")))))
    out = eq.search_batch([tree, nested])   # normalize flattens + dedupes
    assert out[0] is out[1]


def test_search_regex_removed_raises_typed_error(engine):
    store, _docs, _truth = engine
    svc = SearchService(SimCloudTransport(SimCloudStore(store, seed=35)),
                        "index/be", cache_size=8)
    with pytest.raises(DeprecatedAPIError, match="search_regex"):
        svc.search_regex(r"blk_4[0-9]1\b")
    assert svc.stats.cache_lookups == 0      # rejected before the planner


def test_search_regex_shim_routes_through_cache_and_topk(engine,
                                                         monkeypatch):
    monkeypatch.setenv("REPRO_ALLOW_DEPRECATED", "1")
    store, _docs, _truth = engine
    svc = SearchService(SimCloudTransport(SimCloudStore(store, seed=35)),
                        "index/be", cache_size=8)
    with pytest.warns(DeprecationWarning, match="search_regex"):
        r1 = svc.search_regex(r"blk_4[0-9]1\b")
    # the shim is the planner path: cached, counted, equal to search()
    r2 = svc.search(Regex(r"blk_4[0-9]1\b"))
    assert svc.cache_hits == 1 and svc.stats.cache_lookups == 2
    assert r1.texts == r2.texts and r1.refs == r2.refs
    with pytest.warns(DeprecationWarning):
        limited = svc.search_regex(r"blk_4[0-9]1\b", top_k=1)
    assert len(limited.texts) <= 1
