"""Data pipeline determinism + search/RAG serving."""

import jax
import numpy as np

from repro.configs import get_config
from repro.data import make_logs_like, write_corpus
from repro.data.pipeline import IndexedCorpusLoader, PipelineConfig
from repro.index import Builder, BuilderConfig, Term
from repro.models import NULL_RULES, build_model, init_params
from repro.serving import RAGPipeline, SearchService
from repro.storage import (InMemoryBlobStore, SimCloudStore,
                           SimCloudTransport)


def _setup():
    store = InMemoryBlobStore()
    docs = make_logs_like(1500, seed=4)
    corpus = write_corpus(store, "corpus/p", docs, n_blobs=3)
    Builder(BuilderConfig(B=800, F0=1.0, hedge_layers=1)).build(
        corpus, store, "index/p")
    return store, docs


def test_loader_deterministic_across_restarts():
    store, _docs = _setup()
    cfg = PipelineConfig(seq_len=32, batch_size=4, vocab_size=1000, seed=5)
    l1 = IndexedCorpusLoader(SimCloudStore(store, seed=0), "index/p", cfg)
    l2 = IndexedCorpusLoader(SimCloudStore(store, seed=99), "index/p", cfg)
    for step in (0, 3, 17):
        b1, b2 = l1.batch(step), l2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_loader_host_sharding_disjoint():
    store, _docs = _setup()
    cfg = PipelineConfig(seq_len=32, batch_size=4, vocab_size=1000)
    loaders = [IndexedCorpusLoader(SimCloudStore(store, seed=0), "index/p",
                                   cfg, host=h, n_hosts=4) for h in range(4)]
    texts = [set(l._texts) for l in loaders]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not (texts[i] & texts[j])
    assert sum(len(t) for t in texts) > 0


def test_loader_keyword_filter():
    store, docs = _setup()
    cfg = PipelineConfig(seq_len=32, batch_size=2, vocab_size=1000)
    loader = IndexedCorpusLoader(SimCloudStore(store, seed=0), "index/p",
                                 cfg, query=Term("error"))
    assert all("error" in t.lower() for t in loader._texts)
    batch = loader.batch(0)
    assert batch["tokens"].shape == (2, 32)
    assert batch["labels"].shape == (2, 32)


def test_search_service_latency_stats():
    store, docs = _setup()
    svc = SearchService(SimCloudTransport(SimCloudStore(store, seed=0)), "index/p")
    for q in ("error", "block", "info"):
        svc.search(q, top_k=5)
    s = svc.stats.summary()
    assert s["n"] == 3
    assert 0 < s["mean_ms"] < 2000
    assert s["p99_ms"] >= s["p50_ms"]


def test_rag_pipeline_end_to_end():
    store, _docs = _setup()
    cfg = get_config("granite-20b", reduced=True).with_(
        n_layers=2, d_model=64, n_heads=2, n_kv=1, d_ff=128, vocab=512)
    model = build_model(cfg)
    params = init_params(model.param_desc(), jax.random.PRNGKey(0))
    svc = SearchService(SimCloudTransport(SimCloudStore(store, seed=0)), "index/p")
    rag = RAGPipeline(svc, model, params, vocab_size=cfg.vocab,
                      max_context=48)
    out = rag.generate("block", top_k_docs=2, max_new_tokens=4)
    assert out.n_decoded == 4
    assert len(out.retrieved) == 2
    assert out.retrieval_ms > 0
    assert all(0 <= t < cfg.vocab for t in out.tokens)
