"""Blob stores, simulated cloud, and the superpost compaction codec."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.index import codec
from repro.storage import (InMemoryBlobStore, LocalBlobStore, NetworkModel,
                           RangeRequest, SimCloudStore)


# ---------------------------------------------------------------- blobstore
@pytest.mark.parametrize("make", [
    InMemoryBlobStore, lambda: LocalBlobStore(_tmpdir())])
def test_blobstore_roundtrip(make):
    store = make()
    store.put("a/b/blob1", b"hello world")
    assert store.get("a/b/blob1") == b"hello world"
    assert store.get_range(RangeRequest("a/b/blob1", 6, 5)) == b"world"
    assert store.size("a/b/blob1") == 11
    assert store.list("a/") == ["a/b/blob1"]
    store.delete("a/b/blob1")
    assert store.list() == []


def _tmpdir():
    import tempfile
    return tempfile.mkdtemp()


def test_local_store_atomic_overwrite():
    store = LocalBlobStore(_tmpdir())
    store.put("x", b"v1")
    store.put("x", b"v2")
    assert store.get("x") == b"v2"
    assert store.list() == ["x"]


def test_blob_name_escape_rejected():
    store = LocalBlobStore(_tmpdir())
    with pytest.raises(ValueError):
        store.put("../escape", b"nope")


# ----------------------------------------------------------------- simcloud
def test_simcloud_deterministic():
    base = InMemoryBlobStore()
    base.put("b", b"x" * 1000)
    reqs = [RangeRequest("b", 0, 100)] * 8
    s1 = SimCloudStore(base, seed=7)
    s2 = SimCloudStore(base, seed=7)
    _, st1 = s1.fetch_batch(reqs)
    _, st2 = s2.fetch_batch(reqs)
    assert st1.elapsed_s == st2.elapsed_s


def test_simcloud_affine_latency():
    """Fig. 2: latency flat until ~MBs, then linear in bytes."""
    base = InMemoryBlobStore()
    base.put("b", b"x" * (64 << 20))
    model = NetworkModel(jitter_sigma=0.0, tail_prob=0.0)
    cloud = SimCloudStore(base, model=model, seed=0)
    t_small = cloud.fetch(RangeRequest("b", 0, 1024))[1].elapsed_s
    t_2mb = cloud.fetch(RangeRequest("b", 0, 2 << 20))[1].elapsed_s
    t_32mb = cloud.fetch(RangeRequest("b", 0, 32 << 20))[1].elapsed_s
    assert t_small == pytest.approx(model.first_byte_s, rel=0.05)
    assert t_2mb < 2 * t_small                  # still latency-dominated
    assert t_32mb > 5 * t_small                 # bandwidth-dominated


def test_simcloud_parallel_beats_sequential():
    """The paper's core claim, in miniature: one batch of n parallel
    requests is far faster than n dependent round trips."""
    base = InMemoryBlobStore()
    base.put("b", b"x" * 10000)
    reqs = [RangeRequest("b", i * 100, 100) for i in range(16)]
    cloud = SimCloudStore(base, seed=0)
    _, par = cloud.fetch_batch(reqs)
    _, seq = cloud.fetch_chain(reqs)
    assert seq.elapsed_s > 5 * par.elapsed_s


def test_simcloud_hedging_cuts_tail():
    """§IV-G: issue L+, wait for L — tail latency drops."""
    base = InMemoryBlobStore()
    base.put("b", b"x" * 10000)
    model = NetworkModel(tail_prob=0.2, tail_scale=20.0)
    lat_all, lat_hedged = [], []
    for seed in range(300):
        c = SimCloudStore(base, model=model, seed=seed)
        reqs = [RangeRequest("b", 0, 100)] * 6
        lat_all.append(c.fetch_batch(reqs)[1].elapsed_s)
        c2 = SimCloudStore(base, model=model, seed=seed)
        lat_hedged.append(c2.fetch_batch(reqs, wait_for=4)[1].elapsed_s)
    assert np.percentile(lat_hedged, 95) < 0.6 * np.percentile(lat_all, 95)
    assert np.mean(lat_hedged) < np.mean(lat_all)


def test_simcloud_concurrency_queueing():
    base = InMemoryBlobStore()
    base.put("b", b"x" * 1000)
    model = NetworkModel(jitter_sigma=0.0, tail_prob=0.0)
    reqs = [RangeRequest("b", 0, 10)] * 64
    wide = SimCloudStore(base, model=model, concurrency=64, seed=0)
    narrow = SimCloudStore(base, model=model, concurrency=4, seed=0)
    t_wide = wide.fetch_batch(reqs)[1].elapsed_s
    t_narrow = narrow.fetch_batch(reqs)[1].elapsed_s
    assert t_narrow == pytest.approx(16 * t_wide, rel=0.05)


# -------------------------------------------------------------------- codec
@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2**50), max_size=200))
def test_varint_roundtrip(values):
    arr = np.asarray(sorted(values), dtype=np.uint64)
    data = codec.encode_varints(arr)
    out, used = codec.decode_varints(data, len(arr))
    assert used == len(data)
    assert (out == arr).all()


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_superpost_roundtrip(data):
    n = data.draw(st.integers(0, 300))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
    keys = np.unique(rng.integers(0, 2**45, size=n).astype(np.uint64))
    lengths = rng.integers(1, 10_000, size=len(keys)).astype(np.uint64)
    blob = codec.encode_superpost(keys, lengths)
    k2, l2 = codec.decode_superpost(blob)
    assert (k2 == keys).all() and (l2 == lengths).all()


def test_posting_key_split():
    blob_keys = np.array([0, 3, 70000])
    offsets = np.array([0, 12345, (1 << 40) - 1])
    keys = codec.posting_key(blob_keys, offsets)
    b, o = codec.split_posting_key(keys)
    assert (b == blob_keys).all() and (o == offsets).all()


def test_pointers_roundtrip():
    ptrs = [codec.BinPointer(i % 3, i * 17, i + 1) for i in range(100)]
    out = codec.unpack_pointers(codec.pack_pointers(ptrs))
    assert out == ptrs


def test_header_roundtrip_and_magic():
    payload = {"spec": {"B": 10, "L": 2}, "names": ["a", "b"]}
    data = codec.encode_header(payload)
    assert codec.decode_header(data) == payload
    with pytest.raises(ValueError):
        codec.decode_header(b"XXXX" + data[4:])


def test_superpost_compression_beats_raw():
    """Delta-varint must beat 16-byte raw (key, length) pairs on
    clustered postings (the paper's compression claim)."""
    rng = np.random.default_rng(0)
    offsets = np.sort(rng.integers(0, 1 << 24, size=1000).astype(np.uint64))
    keys = codec.posting_key(np.zeros(1000, np.uint64), offsets)
    lengths = rng.integers(50, 300, size=1000).astype(np.uint64)
    blob = codec.encode_superpost(keys, lengths)
    assert len(blob) < 0.5 * 16 * 1000
