"""Cross-layer integrations + CLI-level helpers."""

import numpy as np
import pytest

from repro.core import IoUSketch, SketchSpec
from repro.index.query import And, Or, Term, parse


def test_sketch_bitmap_query_matches_sorted():
    """The Pallas-kernel combine == the sorted-array combine."""
    rng = np.random.default_rng(0)
    postings = {f"w{j}": np.unique(rng.integers(0, 5000, 40))
                .astype(np.uint32) for j in range(200)}
    sketch = IoUSketch.build(postings, SketchSpec(B=120, L=3, seed=1))
    for w in list(postings)[:20]:
        a = sketch.query(w, impl="sorted")
        b = sketch.query(w, impl="bitmap", n_docs=5000)
        np.testing.assert_array_equal(a, b)
        assert set(postings[w].tolist()) <= set(b.tolist())


def test_query_parser():
    assert parse("hello") == Term("hello")
    assert parse("a b") == And((Term("a"), Term("b")))
    assert parse("a AND b") == And((Term("a"), Term("b")))
    q = parse("a b OR c")
    assert isinstance(q, Or)
    assert q.items[0] == And((Term("a"), Term("b")))
    assert q.items[1] == Term("c")
    # operator sugar
    assert (Term("x") & Term("y")) == And((Term("x"), Term("y")))
    assert (Term("x") | Term("y")) == Or((Term("x"), Term("y")))


def test_elastic_mesh_chooser():
    import subprocess
    import sys
    import os
    import json
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    # pin cpu: forced host device count still applies, and probing the
    # container's TPU plugin (unset JAX_PLATFORMS) can hang for minutes
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=src)
    code = (
        "import json, jax\n"
        "from repro.launch.elastic import choose_mesh\n"
        "m1 = choose_mesh(8, prefer_model=4)\n"
        "m2 = choose_mesh(6, prefer_model=4)\n"   # 6 % 4 != 0 -> degrade
        "print(json.dumps({'m1': dict(m1.shape), 'm2': dict(m2.shape)}))\n")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-1500:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["m1"] == {"data": 2, "model": 4}
    assert res["m2"] == {"data": 3, "model": 2}


def test_dryrun_artifact_schema():
    """Dry-run artifacts (if present) obey the schema report.py reads."""
    import glob
    import json
    import os
    paths = glob.glob(os.path.join(os.path.dirname(__file__), "..",
                                   "experiments", "dryrun", "*.json"))
    if not paths:
        pytest.skip("no dry-run artifacts in this checkout")
    ok = skipped = 0
    for p in paths:
        rec = json.load(open(p))
        assert rec["status"] in ("ok", "skipped", "error"), p
        assert {"arch", "cell", "mesh"} <= set(rec)
        if rec["status"] == "ok":
            ok += 1
            rl = rec["roofline"]
            for key in ("t_compute_s", "t_memory_s", "t_collective_s",
                        "bottleneck", "roofline_fraction"):
                assert key in rl, (p, key)
            assert rl["t_bound_s"] >= max(
                rl["t_compute_s"], rl["t_memory_s"],
                rl["t_collective_s"]) * 0.999
            assert rec["memory"]["temp_bytes"] >= 0
        elif rec["status"] == "skipped":
            skipped += 1
            assert rec["cell"] == "long_500k"
    assert ok > 0
    # errors are bugs in the system (dry-run contract)
    errors = [p for p in paths
              if json.load(open(p))["status"] == "error"]
    assert not errors, errors[:3]
