"""Per-architecture smoke tests on REDUCED configs (CPU, 1 device):
one forward/train step asserting output shapes + no NaNs, plus
prefill→decode consistency. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import MoEConfig
from repro.models import NULL_RULES, build_model, init_params, param_count

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(4, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(4, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.kind == "vlm":
        s_img = 16
        batch["tokens"] = batch["tokens"][:, :S - s_img]
        batch["patches"] = jnp.asarray(
            rng.normal(0, 1, (B, s_img, cfg.d_model)), jnp.bfloat16)
        pos = np.stack([np.arange(S)] * 3, -1)[None].repeat(B, 0)
        batch["positions"] = jnp.asarray(pos, jnp.int32)
    if cfg.kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, S, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_desc(), KEY)
    assert param_count(params) > 10_000
    loss = jax.jit(lambda p, b: model.loss_fn(p, b, NULL_RULES))(
        params, _batch(cfg))
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    # loss should be near ln(vocab) at random init
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.0 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    from repro.training.optimizer import (OptimizerConfig, adamw_update,
                                          init_opt_state)
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_desc(), KEY)
    opt = init_opt_state(params)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, NULL_RULES))(params)
        params, opt, metrics = adamw_update(params, grads, opt, ocfg)
        return params, opt, loss, metrics

    batch = _batch(cfg, B=2, S=32)
    params, opt, loss, metrics = step(params, opt, batch)
    assert jnp.isfinite(loss)
    assert jnp.isfinite(metrics["grad_norm"])
    for leaf in jax.tree.leaves(params):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all(), arch


@pytest.mark.parametrize("arch", ["qwen3-32b", "granite-20b",
                                  "mixtral-8x22b", "seamless-m4t-medium",
                                  "rwkv6-3b", "jamba-v0.1-52b"])
def test_prefill_decode_consistency(arch):
    """Decoding token-by-token after prefill matches the full forward."""
    cfg = get_config(arch, reduced=True)
    if cfg.moe:   # drop-free capacity so results are batch-size-invariant
        cfg = cfg.with_(moe=MoEConfig(
            cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.every,
            capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k))
    model = build_model(cfg)
    params = init_params(model.param_desc(), jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    S, EXTRA, B = 32, 3, 2
    toks = jnp.asarray(rng.integers(4, cfg.vocab, (B, S + EXTRA)), jnp.int32)
    bp, bf = {"tokens": toks[:, :S]}, {"tokens": toks}
    if cfg.kind == "encdec":
        frames = jnp.asarray(rng.normal(0, 1, (B, 16, cfg.d_model)),
                             jnp.bfloat16)
        bp["frames"] = frames
        bf["frames"] = frames
    kw = {} if cfg.kind == "rwkv" else {"pad_to": S + EXTRA}
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, NULL_RULES, **kw))(params, bp)
    dec = jax.jit(lambda p, c, b: model.decode_step(p, c, b, NULL_RULES))
    for t in range(EXTRA):
        logits, cache = dec(params, cache,
                            {"tokens": toks[:, S + t:S + t + 1]})
    logits_ref, _ = jax.jit(
        lambda p, b: model.prefill(p, b, NULL_RULES))(params, bf)
    err = float(jnp.max(jnp.abs(logits - logits_ref)))
    scale = float(jnp.max(jnp.abs(logits_ref)))
    assert err < 0.25 * max(scale, 1.0), (arch, err, scale)


def test_vlm_decode_runs():
    cfg = get_config("qwen2-vl-72b", reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_desc(), KEY)
    cache_desc = model.cache_desc(2, 64)
    cache = init_params(cache_desc, KEY)
    pos = jnp.broadcast_to(
        jnp.array([5, 5, 5], jnp.int32)[None, None], (2, 1, 3))
    batch = {"tokens": jnp.ones((2, 1), jnp.int32), "positions": pos}
    cache = dict(cache, pos=jnp.int32(5))
    logits, cache2 = jax.jit(
        lambda p, c, b: model.decode_step(p, c, b, NULL_RULES))(
        params, cache, batch)
    assert logits.shape == (2, cfg.vocab)
    assert jnp.isfinite(logits).all()
    assert int(cache2["pos"]) == 6


def test_moe_matches_dense_reference():
    """Capacity-based MoE == per-token expert loop when nothing drops."""
    from repro.models import blocks
    cfg = get_config("phi3.5-moe-42b-a6.6b", reduced=True).with_(
        moe=MoEConfig(n_experts=4, top_k=2, every=1, capacity_factor=2.0))
    model = build_model(cfg)
    params = init_params(model.param_desc(), KEY)
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, cfg.d_model)), jnp.bfloat16)
    out = blocks.moe_ffn(x, lp["moe"], cfg, NULL_RULES)
    p = lp["moe"]
    xf = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xf.astype(jnp.float32) @ p["router"], -1)
    tv, ti = jax.lax.top_k(probs, 2)
    tv = tv / tv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf, dtype=jnp.float32)
    for e in range(cfg.moe.n_experts):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_in"][e])
        y = (h @ p["w_out"][e]).astype(jnp.float32)
        ref += y * ((ti == e) * tv).sum(-1)[:, None]
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, cfg.d_model), np.float32),
        np.asarray(ref), atol=0.06)


def test_blockwise_attention_matches_kernel_ref():
    from repro.models.blocks import blockwise_attention
    from repro.kernels.attention import attention as kernel_attention
    rng = np.random.default_rng(0)
    B, S, H, KV, dh = 2, 128, 4, 2, 64
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, dh)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    out_m = blockwise_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                causal=True, window=None, chunk=32,
                                rules=NULL_RULES)
    out_k = kernel_attention(q, k, v, causal=True, impl="pallas")
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_k),
                               atol=3e-5)


def test_chunked_ce_matches_naive():
    from repro.models.losses import chunked_cross_entropy
    rng = np.random.default_rng(0)
    B, S, D, V = 2, 64, 32, 100
    x = jnp.asarray(rng.normal(0, 1, (B, S, D)), jnp.float32)
    head = jnp.asarray(rng.normal(0, 0.1, (V, D)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    labels = labels.at[0, :5].set(-1)       # masked positions
    got = chunked_cross_entropy(x, labels, head, NULL_RULES, chunk=16)
    logits = x @ head.T
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None],
                               -1)[..., 0]
    valid = labels >= 0
    want = (nll * valid).sum() / valid.sum()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
