"""§Perf optimization paths must be semantically equivalent to baselines:
grouped MoE dispatch, triangular attention, int8 KV decode, bf16-grad CE."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models import NULL_RULES, build_model, init_params
from repro.models import blocks
from repro.models.blocks import blockwise_attention, set_attn_triangular
from repro.models.losses import chunked_cross_entropy, set_bf16_grad_barrier


def test_grouped_moe_matches_global_when_dropfree():
    cfg = get_config("phi3.5-moe-42b-a6.6b", reduced=True).with_(
        moe=MoEConfig(n_experts=4, top_k=2, every=1, capacity_factor=2.0))
    model = build_model(cfg)
    params = init_params(model.param_desc(), jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, cfg.d_model)), jnp.bfloat16)
    out_g = blocks.moe_ffn_grouped(x, lp["moe"], cfg, NULL_RULES)
    out_b = blocks.moe_ffn_global(x, lp["moe"], cfg, NULL_RULES)
    np.testing.assert_allclose(np.asarray(out_g, np.float32),
                               np.asarray(out_b, np.float32), atol=0.05)


def test_triangular_attention_matches_scan():
    rng = np.random.default_rng(0)
    B, S, H, KV, dh = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, dh)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    kwargs = dict(q_positions=pos, kv_positions=pos, causal=True,
                  chunk=16, rules=NULL_RULES)
    try:
        for window in (None, 48):
            base = blockwise_attention(q, k, v, window=window, **kwargs)
            set_attn_triangular(True)
            tri = blockwise_attention(q, k, v, window=window, **kwargs)
            set_attn_triangular(False)
            np.testing.assert_allclose(np.asarray(tri), np.asarray(base),
                                       atol=1e-5)
    finally:
        set_attn_triangular(False)


def test_int8_kv_decode_close_to_bf16():
    rng = np.random.default_rng(0)
    S, EXTRA, B = 32, 3, 2
    base = get_config("qwen3-32b", reduced=True)
    toks = jnp.asarray(rng.integers(4, base.vocab, (B, S + EXTRA)),
                       jnp.int32)
    outs = {}
    for name, cfg in (("bf16", base), ("int8", base.with_(kv_quant=True))):
        model = build_model(cfg)
        params = init_params(model.param_desc(), jax.random.PRNGKey(1))
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, NULL_RULES, pad_to=S + EXTRA)
        )(params, {"tokens": toks[:, :S]})
        dec = jax.jit(lambda p, c, b: model.decode_step(p, c, b, NULL_RULES))
        for t in range(EXTRA):
            logits, cache = dec(params, cache,
                                {"tokens": toks[:, S + t:S + t + 1]})
        outs[name] = logits
    err = float(jnp.max(jnp.abs(outs["bf16"] - outs["int8"])))
    scale = float(jnp.max(jnp.abs(outs["bf16"])))
    assert err < 0.1 * max(scale, 1.0), (err, scale)
    assert (jnp.argmax(outs["bf16"], -1) == jnp.argmax(outs["int8"], -1)
            ).mean() > 0.99


def test_bf16_grad_ce_matches_fp32():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 64, 32)), jnp.bfloat16)
    head = jnp.asarray(rng.normal(0, 0.1, (100, 32)), jnp.bfloat16)
    labels = jnp.asarray(rng.integers(0, 100, (2, 64)), jnp.int32)

    def f(x, h):
        return chunked_cross_entropy(x, labels, h, NULL_RULES, chunk=16)

    try:
        l1, g1 = jax.value_and_grad(f, argnums=(0, 1))(x, head)
        set_bf16_grad_barrier(True)
        l2, g2 = jax.value_and_grad(f, argnums=(0, 1))(x, head)
    finally:
        set_bf16_grad_barrier(False)
    assert abs(float(l1) - float(l2)) < 1e-5
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=0.01)


def test_apply_variant_profiles():
    from repro.launch.steps import apply_variant
    cfg = get_config("qwen3-32b")
    c, prof, gd = apply_variant(cfg, "train_4k", "baseline")
    assert prof == "baseline" and gd == "fp32" and c.moe_impl == "global"
    c, prof, gd = apply_variant(cfg, "train_4k", "opt")
    assert prof == "fsdp_only" and gd == "bf16" and c.ce_chunk > 4096
    c, prof, _ = apply_variant(cfg, "decode_32k", "opt")
    assert prof == "decode_tp" and c.kv_quant
    # mixtral (8e, no clean expert↔shard mapping): grouped dispatch
    moe_cfg = get_config("mixtral-8x22b")
    c, prof, _ = apply_variant(moe_cfg, "train_4k", "opt")
    assert c.moe_impl == "grouped" and prof == "baseline"
    # MoE decode keeps FSDP weight sharding (no resident-TP replication)
    c, prof, _ = apply_variant(moe_cfg, "decode_32k", "opt")
    assert prof == "baseline" and not c.kv_quant
    # phi (16e == model axis): global dispatch already expert-local
    phi_cfg = get_config("phi3.5-moe-42b-a6.6b")
    c, prof, _ = apply_variant(phi_cfg, "train_4k", "opt")
    assert c.moe_impl == "global"
