"""Cluster-fused combine kernel + global top-K sampling budget.

Load-bearing invariant: the sampling budget may only change *how many
bytes round 2 moves*, never *which documents* the cluster returns — the
budgeted (`budget="global"`, ~k docs cluster-wide) and unbudgeted
(`budget="per_shard"`, ~n_shards·k docs) fused paths must be
byte-identical on every corpus, shard count, and candidate skew,
including the degenerate all-candidates-on-one-shard case.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import make_logs_like, write_corpus
from repro.data.corpus import DocRef
from repro.index import And, BuilderConfig, Index, Not, Or, Term
from repro.index.planner import shard_quotas
from repro.serving import (SearchService, ShardedIndex, partition_corpus,
                           shard_of_ref)
from repro.serving.cluster import _topk_select
from repro.storage import InMemoryBlobStore

CFG = BuilderConfig(B=900, F0=1.0, index_ngrams=3)

QUERIES = [
    "error", "info",
    And((Term("info"), Term("block"))),
    Or((Term("warn"), Term("node7"))),
    And((Term("info"), Not(Term("block")))),
]


def _build(n_docs, n_shards, seed, n_blobs=4):
    store = InMemoryBlobStore()
    docs = make_logs_like(n_docs, seed=seed)
    corpus = write_corpus(store, "corpus/fc", docs, n_blobs=n_blobs)
    cluster = ShardedIndex.build(corpus, CFG, store, "cluster/fc",
                                 n_shards=n_shards)
    return store, docs, corpus, cluster


def _identical(a, b):
    return all(x.texts == y.texts and x.refs == y.refs
               for x, y in zip(a, b))


# -------------------------------------------------------------- shard_quotas
def test_shard_quotas_budget_and_caps():
    counts = [100, 50, 10, 0]
    quotas = shard_quotas(counts, k=5, F0s=[1.0] * 4)
    assert len(quotas) == 4
    # never over-fetch a shard, never fetch from an empty one
    assert all(q <= c for q, c in zip(quotas, counts))
    assert quotas[3] == 0
    # every shard with candidates contributes at least one doc
    assert all(q >= 1 for q, c in zip(quotas, counts) if c > 0)
    # the global budget stays well under the per-shard baseline
    assert sum(quotas) < sum(counts)


def test_shard_quotas_total_matches_global_sample():
    from repro.core.topk import sample_size
    counts = [400, 300, 200, 100]
    k, F0s = 8, [1.0] * 4
    rk = min(sample_size(sum(counts), k, float(sum(F0s))), sum(counts))
    quotas = shard_quotas(counts, k, F0s)
    # largest-remainder allocation hits the global budget exactly
    # (min-1 floors can only push it up, and none bind here)
    assert sum(quotas) == rk
    # proportionality: bigger shards get bigger quotas
    assert quotas == sorted(quotas, reverse=True)


def test_shard_quotas_edge_cases():
    assert shard_quotas([], k=5, F0s=[]) == []
    assert shard_quotas([0, 0], k=5, F0s=[1.0, 1.0]) == [0, 0]
    # k >= total candidates: fetch everything
    assert shard_quotas([3, 2], k=10, F0s=[1.0, 1.0]) == [3, 2]
    # deterministic: same inputs, same quotas
    a = shard_quotas([17, 91, 43], k=4, F0s=[1.0] * 3)
    assert a == shard_quotas([17, 91, 43], k=4, F0s=[1.0] * 3)


# -------------------------------------------------------------- _topk_select
def _ref(i):
    return DocRef("b", i * 10, 10)


def test_topk_select_dedups_and_orders():
    # doc 1 appears on two shards: keep the lowest (pos, shard) copy
    refs = [[_ref(1), _ref(2)], [_ref(1), _ref(3)]]
    texts = [["one", "two"], ["one'", "three"]]
    out_r, out_t = _topk_select(refs, texts, k=3)
    assert out_r == [_ref(1), _ref(2), _ref(3)]
    assert out_t == ["one", "two", "three"]          # shard-0 copy wins


def test_topk_select_k_exceeds_pool():
    refs = [[_ref(1)], [_ref(2)]]
    texts = [["a"], ["b"]]
    out_r, _ = _topk_select(refs, texts, k=10)
    assert sorted((r.offset for r in out_r)) == [10, 20]


# ---------------------------------------------------------- fused vs plain
@pytest.fixture(scope="module")
def fused_fixture():
    return _build(900, 4, seed=13)


def test_fused_full_results_identical_to_plain(fused_fixture):
    store, _docs, corpus, cluster = fused_fixture
    # the unsharded reference needs a bigger sketch budget than one
    # shard's slice; verified results are config-independent
    mono = Index.build(corpus, BuilderConfig(B=1800, F0=1.0,
                                             index_ngrams=3),
                       store, "index/fc-mono")
    cs = cluster.searcher()
    expect = mono.searcher().query_batch(QUERIES)
    assert _identical(expect, cs.query_batch(QUERIES, fused=False))
    assert _identical(expect, cs.query_batch(QUERIES, fused=True))
    cs.close()


def test_fused_budget_paths_byte_identical(fused_fixture):
    _store, _docs, _corpus, cluster = fused_fixture
    cs = cluster.searcher(fused=True)
    for k in (1, 5, 20):
        a = cs.query_batch(QUERIES, top_k=k, budget="global")
        b = cs.query_batch(QUERIES, top_k=k, budget="per_shard")
        assert _identical(a, b)
    cs.close()


def test_fused_budget_fetches_fewer_bytes():
    """At 16 shards the per-shard baseline over-fetches ~n_shards·k docs
    while the global budget stays near k — ≥2× fewer round-2 bytes.

    Uses positive queries only: a NOT branch voids the Eq. 6 false-
    positive model (the sketch can't exclude, so actual FPs ≫ F0) and
    may legitimately trip the unbudgeted completion fallback — that
    path keeps byte-identity but forfeits the byte savings."""
    _store, _docs, _corpus, cluster = _build(900, 16, seed=13)
    positive = [q for q in QUERIES
                if not isinstance(q, And) or
                not any(isinstance(c, Not) for c in q.items)]
    cs = cluster.searcher(fused=True)
    cs.query_batch(positive, top_k=5, budget="global")
    bytes_global = sum(cs.last_scatter.round2_bytes)
    cs.query_batch(positive, top_k=5, budget="per_shard")
    bytes_per_shard = sum(cs.last_scatter.round2_bytes)
    assert 0 < bytes_global * 2 <= bytes_per_shard
    cs.close()


def test_fused_scatter_report_fields(fused_fixture):
    _store, _docs, _corpus, cluster = fused_fixture
    cs = cluster.searcher(fused=True)
    out = cs.query_batch(QUERIES, top_k=5)
    rep = cs.last_scatter
    assert rep.fused and rep.budget == "global"
    assert len(rep.shard_candidates) == 4
    assert sum(rep.shard_candidates) > 0
    assert len(rep.round2_bytes) == len(rep.round2_requests) == 4
    # candidate accounting agrees with per-query stats
    assert sum(rep.shard_candidates) == \
        sum(r.stats.n_candidates for r in out)
    # a full (non-top-K) fused round reports no budget
    cs.query_batch(QUERIES)
    assert cs.last_scatter.fused and cs.last_scatter.budget is None
    cs.close()


def test_latency_stats_surface_scatter_counters(fused_fixture):
    _store, _docs, _corpus, cluster = fused_fixture
    svc = SearchService(cluster)
    svc.searcher.fused = True
    svc.search("error", top_k=5)
    svc.search_batch(QUERIES, top_k=5)
    s = svc.stats.summary()
    assert s["scatter_rounds"] == 2 and s["fused_rounds"] == 2
    assert len(s["shard_candidates"]) == 4
    assert s["round2_bytes"] == sum(s["round2_bytes_per_shard"])
    assert s["round2_requests"] == sum(s["round2_requests_per_shard"])
    assert s["round2_bytes"] > 0
    svc.close()


# --------------------------------------------------- property: byte-identity
@pytest.mark.parametrize("n_shards", [1, 4, 16, 64])
def test_budget_identity_across_shard_counts(n_shards):
    _store, _docs, _corpus, cluster = _build(420, n_shards, seed=29)
    cs = cluster.searcher(fused=True)
    a = cs.query_batch(QUERIES, top_k=7, budget="global")
    b = cs.query_batch(QUERIES, top_k=7, budget="per_shard")
    assert _identical(a, b)
    assert all(len(r.texts) <= 7 for r in a)
    cs.close()


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**16))
def test_budget_identity_property(seed):
    rng = np.random.default_rng(seed)
    n_docs = int(rng.integers(150, 450))
    n_shards = int(rng.choice([1, 4, 16, 64]))
    k = int(rng.integers(1, 16))
    _store, _docs, _corpus, cluster = _build(n_docs, n_shards, seed=seed)
    cs = cluster.searcher(fused=True)
    queries = [QUERIES[i] for i in rng.choice(len(QUERIES), 3, replace=False)]
    a = cs.query_batch(queries, top_k=k, budget="global")
    b = cs.query_batch(queries, top_k=k, budget="per_shard")
    assert _identical(a, b)
    cs.close()


def test_budget_identity_all_candidates_on_one_shard():
    """Worst-case skew: every match for the probe token lives on one
    shard.  Built by swapping the token for a same-byte-length decoy in
    every doc routed off shard 0 — lengths (hence offsets, hence blob
    routing) are unchanged, only the content skews."""
    n_shards = 16
    store = InMemoryBlobStore()
    docs = make_logs_like(500, seed=41)
    # seed the probe token everywhere first (same byte length as decoy)
    docs = [d + " zebraseek" for d in docs]
    corpus = write_corpus(store, "corpus/skew", docs, n_blobs=4)
    keep = {r for r in corpus.refs if shard_of_ref(r, n_shards) == 0}
    docs = [d if r in keep else d.replace("zebraseek", "yuccapath")
            for d, r in zip(docs, corpus.refs)]
    corpus = write_corpus(store, "corpus/skew", docs, n_blobs=4)
    assert all(shard_of_ref(r, n_shards) == 0
               for r, d in zip(corpus.refs, docs) if "zebraseek" in d)

    cluster = ShardedIndex.build(corpus, CFG, store, "cluster/skew",
                                 n_shards=n_shards)
    cs = cluster.searcher(fused=True)
    a = cs.query_batch(["zebraseek"], top_k=5, budget="global")
    b = cs.query_batch(["zebraseek"], top_k=5, budget="per_shard")
    assert _identical(a, b)
    assert len(a[0].texts) == 5
    assert all("zebraseek" in t for t in a[0].texts)
    # round-2 fetches only touch the one shard that holds candidates
    rep = cs.last_scatter
    hot = [s for s, n in enumerate(rep.round2_requests) if n > 0]
    assert hot == [0]
    cs.close()
