#!/usr/bin/env python
"""Run the repo's invariant linter (see docs/static_analysis.md).

Exit status:
  0  clean (all findings baselined; in --strict mode the baseline is
     also exact — no stale entries)
  1  un-baselined findings
  2  stale baseline entries under --strict (the debt they excused is
     fixed; delete them — the baseline shrinks, never grows)

Usage:
  python scripts/lint_invariants.py            # lint src/repro + benchmarks
  python scripts/lint_invariants.py --strict   # CI mode
  python scripts/lint_invariants.py src/repro/serving/frontend.py
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis.lint import apply_baseline, load_baseline, run_lint  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files to lint (default: the whole tree)")
    parser.add_argument("--root", type=Path, default=REPO_ROOT,
                        help="tree root (default: the repo)")
    parser.add_argument("--baseline", type=Path, default=None,
                        help="allowlist file (default: "
                             "<root>/src/repro/analysis/baseline.toml)")
    parser.add_argument("--strict", action="store_true",
                        help="also fail on stale baseline entries")
    args = parser.parse_args(argv)

    root = args.root.resolve()
    files = [p if p.is_absolute() else Path.cwd() / p
             for p in args.paths] or None
    findings = run_lint(root, files)

    baseline_path = (args.baseline
                     or root / "src" / "repro" / "analysis" / "baseline.toml")
    entries = load_baseline(baseline_path) if baseline_path.exists() else []
    remaining, unused = apply_baseline(findings, entries)

    for finding in remaining:
        print(finding.render())
    if remaining:
        print(f"\n{len(remaining)} finding(s) "
              f"({len(findings) - len(remaining)} baselined)")
        return 1

    # partial runs (explicit paths) can't judge baseline staleness:
    # entries for unlinted files would look unused
    if args.strict and files is None and unused:
        for entry in unused:
            print(f"stale baseline entry: {entry.rule} @ {entry.path} "
                  f"({entry.reason}) — the violation is gone; delete the "
                  "entry")
        return 2

    print(f"clean: 0 findings ({len(entries)} baselined, "
          f"{len(findings)} total matched)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
