"""Markdown link checker for README.md and docs/ (the docs CI gate).

Checks every internal markdown link in the repo's documentation:

  * relative file targets must exist (``[x](docs/foo.md)``,
    ``[x](../PAPER.md)``);
  * anchor fragments must match a real heading in the target file,
    using GitHub's slug rules (``[x](foo.md#some-heading)``, ``#frag``
    within the same file);
  * external links (http/https/mailto) are NOT fetched — this gate is
    fast, offline, and deterministic.

Fenced code blocks are stripped before scanning, so example code can
mention ``[x](y)`` freely. Exit status is non-zero when any link is
broken; the report lists ``file:line`` for each.

    python scripts/check_docs.py            # checks README.md + docs/
    python scripts/check_docs.py FILES...   # or an explicit file set
"""

from __future__ import annotations

import glob
import os
import re
import sys

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
FENCE_RE = re.compile(r"^(```|~~~)")
EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code/links, lower,
    drop punctuation except hyphens/underscores, spaces to hyphens."""
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)  # [t](u) -> t
    text = re.sub(r"[*_`]", "", text)
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def strip_fences(lines: list[str]) -> list[str]:
    """Blank out fenced code blocks, keeping line numbers stable."""
    out: list[str] = []
    in_fence = False
    for line in lines:
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            out.append("")
            continue
        out.append("" if in_fence else line)
    return out


def heading_slugs(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        lines = strip_fences(f.read().splitlines())
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    for line in lines:
        m = HEADING_RE.match(line)
        if not m:
            continue
        base = github_slug(m.group(2))
        n = counts.get(base, 0)
        counts[base] = n + 1
        slugs.add(base if n == 0 else f"{base}-{n}")
    return slugs


def check_file(path: str, repo_root: str,
               slug_cache: dict[str, set[str]]) -> list[str]:
    errors: list[str] = []
    with open(path, encoding="utf-8") as f:
        lines = strip_fences(f.read().splitlines())
    for lineno, line in enumerate(lines, start=1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(EXTERNAL):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            if target == "":
                dest = path                      # same-file anchor
            else:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(path), target))
                abs_dest = os.path.abspath(dest)
                if abs_dest != repo_root and \
                        not abs_dest.startswith(repo_root + os.sep):
                    errors.append(f"{path}:{lineno}: link escapes repo: "
                                  f"{m.group(1)}")
                    continue
                if not os.path.exists(dest):
                    errors.append(f"{path}:{lineno}: broken link target: "
                                  f"{m.group(1)}")
                    continue
            if frag is not None:
                if not dest.endswith(".md"):
                    continue                     # anchors only in markdown
                if dest not in slug_cache:
                    slug_cache[dest] = heading_slugs(dest)
                if github_slug(frag) not in slug_cache[dest]:
                    errors.append(f"{path}:{lineno}: broken anchor "
                                  f"#{frag} in {dest}")
    return errors


RULE_DECL_RE = re.compile(r'^\s*id\s*=\s*"([A-Z][A-Z0-9-]*)"', re.M)
RULE_TOKEN_RE = re.compile(r"\b[A-Z][A-Z0-9]*(?:-[A-Z][A-Z0-9]*)+\b")
# placeholders the catalog uses when explaining the pragma syntax
RULE_PLACEHOLDERS = {"RULE-ID"}


def check_rule_catalog(repo_root: str) -> list[str]:
    """docs/static_analysis.md and analysis/lint.py must agree on the
    rule set: every declared rule id is documented, every rule-shaped
    token in the catalog exists in code."""
    lint_py = os.path.join(repo_root, "src", "repro", "analysis", "lint.py")
    catalog = os.path.join(repo_root, "docs", "static_analysis.md")
    errors: list[str] = []
    if not os.path.exists(lint_py) or not os.path.exists(catalog):
        return [f"rule catalog: missing {p}" for p in (lint_py, catalog)
                if not os.path.exists(p)]
    with open(lint_py, encoding="utf-8") as f:
        declared = set(RULE_DECL_RE.findall(f.read()))
    with open(catalog, encoding="utf-8") as f:
        mentioned = set(RULE_TOKEN_RE.findall(f.read())) - RULE_PLACEHOLDERS
    for rule in sorted(declared - mentioned):
        errors.append(f"{catalog}: rule {rule} is declared in "
                      f"{lint_py} but missing from the catalog")
    for rule in sorted(mentioned - declared):
        errors.append(f"{catalog}: mentions rule-like token {rule} that "
                      f"no rule in {lint_py} declares")
    return errors


def default_files(repo_root: str) -> list[str]:
    files = [os.path.join(repo_root, "README.md")]
    files += sorted(glob.glob(os.path.join(repo_root, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def run(files: list[str] | None = None,
        repo_root: str | None = None) -> list[str]:
    root = os.path.abspath(repo_root or
                           os.path.join(os.path.dirname(__file__), ".."))
    targets = files if files else default_files(root)
    slug_cache: dict[str, set[str]] = {}
    errors: list[str] = []
    for path in targets:
        errors += check_file(path, root, slug_cache)
    if files is None:          # full-default runs also pin the rule catalog
        errors += check_rule_catalog(root)
    return errors


def main() -> int:
    files = sys.argv[1:] or None
    errors = run(files)
    if errors:
        print(f"{len(errors)} broken doc link(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    checked = files or default_files(
        os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
    print(f"docs OK: {len(checked)} file(s), no broken internal links")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
