"""Assemble EXPERIMENTS.md from dry-run artifacts + the perf log.

    PYTHONPATH=src python scripts/make_experiments_md.py
"""

import io
import os
import sys
from contextlib import redirect_stdout

sys.path.insert(0, "src")

from repro.configs import ARCHS, cells_for  # noqa: E402
from repro.launch import report  # noqa: E402

HEADER = """\
# EXPERIMENTS — Airphant-JAX

Paper: "AIRPHANT: Cloud-oriented Document Indexing" (Chockchowwat, Sood,
Park — UIUC, 2021). Container: CPU-only; TPU v5e is the modelled target
(197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI). Meshes: single-pod
16×16 (256 chips), multi-pod 2×16×16 (512 chips), built on 512 placeholder
host devices. Cost source: trip-count-aware HLO analysis of the compiled
SPMD program (`repro.launch.hlo_cost`) — XLA's `cost_analysis()` counts
while-loop bodies once and is under-counted for scanned models; ours
multiplies loop bodies by `known_trip_count` and models collective wire
bytes with ring formulas (validated against analytic counts in
`tests/test_hlo_cost.py`). Collective reductions are counted at their
unpromoted width (XLA:CPU promotes bf16 sums to f32; TPU does not).

Two variants per cell:
* **baseline** — the paper-faithful-naive first implementation
  (FSDP×Megatron-TP sharding, global-capacity MoE dispatch, full-T causal
  attention, fp32 gradient flow, bf16 KV cache);
* **opt** — the §Perf hillclimb configuration (grouped MoE dispatch,
  pure-FSDP dense training, triangular causal attention, bf16 gradient
  flow, full-sequence CE, resident-TP decode weights, int8 KV cache).

Reproduce: `PYTHONPATH=src python -m repro.launch.dryrun --all`
(+ `--variant opt`), then `PYTHONPATH=src python scripts/make_experiments_md.py`.

## Paper-validation summary (benchmarks/run.py against the paper's claims)

| paper claim | our measurement (simulated cloud) | verdict |
|---|---|---|
| Fig. 2 affine latency: flat ≲2 MB, then linear | 1 KiB→2 MiB: 1.00→1.70×; 32 MiB: 12.2× | ✓ |
| Fig. 5: FP/query drops ~exponentially in L, matches F(L) | L=1..6 observed 38.7→0.07 vs F(L) 46.8→0.12 | ✓ |
| Fig. 6: Airphant fastest end-to-end | 1.15× vs HashTable, 2.04× vs B-tree/skip list (mean); larger at p99 | ✓ (ratios are corpus-scale-dependent; paper's 379× HashTable gap needs 1e8-doc corpora) |
| Fig. 7: milder cross-region slowdown | 7.00× vs 7.12× (us→asia), direction reproduced; gap grows with payload | ✓ |
| Fig. 8: baselines wait-heavy vs download-heavy; Airphant minimizes both | B-tree wait 132 ms; HashTable download-heavy (5× Airphant's download); Airphant lowest wait | ✓ |
| Fig. 9 / §V-C: decoupled wins at scale, lim = 3.29× | asymptote = 3.29× exactly (same constants) | ✓ exact |
| Fig. 10: optimizer picks small L*; FP ≈ 0 by L=4 | optimizer picks L*=2 on the log corpus (paper: L*=2 on HDFS); FP→0 at L≥4 | ✓ |
| Table II σ_X | Cranfield-shaped corpus σ_X = 0.51 (paper: 0.51) | ✓ exact |
| Fig. 14: lookup 2.79× faster than B-tree | 3.40× mean, 2.18× p99 | ✓ |
| §IV-D top-K: ~23 samples for top-10 | sample_size(·,10,1,1e-6) = 23 | ✓ exact |
| §IV-G hedging cuts tails | p95 −40%+ at 20% straggler rate | ✓ |

Full CSV: `bench_output.txt`.

"""

PERF_REF = """
## Perf — hillclimb log

See `experiments/PERF_LOG.md` for the full hypothesis → change → measure →
validate iteration log (3 hillclimbed cells + refuted hypotheses).
Headline, single-pod t_bound:

"""


def main() -> None:
    buf = io.StringIO()
    with redirect_stdout(buf):
        recs = report.load("experiments/dryrun")
        print(HEADER)
        print("## Dry-run (single-pod 16×16 = 256 chips, baseline)\n")
        print(report.dryrun_table(
            [r for r in recs if r["mesh"] == "single"]))
        print("\n## Dry-run (multi-pod 2×16×16 = 512 chips, baseline)\n")
        print(report.dryrun_table(
            [r for r in recs if r["mesh"] == "multi"]))
        print("\n## Roofline (single-pod, baseline)\n")
        print(report.roofline_table(recs, "baseline"))
        print("\n## Roofline (single-pod, optimized)\n")
        print(report.roofline_table(recs, "opt"))
        print("\n## Roofline (multi-pod, baseline)\n")
        print(report.roofline_table(recs, "baseline", mesh="multi"))
        print("\n## Roofline (multi-pod, optimized)\n")
        print(report.roofline_table(recs, "opt", mesh="multi"))
        print(PERF_REF)
        cells = [(a, c) for a in ARCHS for c in cells_for(a)]
        print(report.compare_table(recs, cells))
        print()
        with open("experiments/PERF_LOG.md") as f:
            print(f.read())
    with open("EXPERIMENTS.md", "w") as f:
        f.write(buf.getvalue())
    print("wrote EXPERIMENTS.md", len(buf.getvalue()), "bytes")


if __name__ == "__main__":
    main()
