"""Quickstart: build an IoU Sketch index on (simulated) cloud storage and
search it — the paper's Figure 1 flow, end to end.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.data import make_logs_like, write_corpus
from repro.index import And, Builder, BuilderConfig, Searcher, Term
from repro.storage import InMemoryBlobStore, SimCloudStore


def main() -> None:
    # 1. put a corpus in "cloud storage" (log lines, Loghub-style)
    store = InMemoryBlobStore()
    docs = make_logs_like(5000, seed=1)
    corpus = write_corpus(store, "corpus/logs", docs, n_blobs=4)
    print(f"corpus: {corpus.n_docs} documents in 4 blobs")

    # 2. Builder: profile -> optimize (Algorithm 1) -> compact -> persist
    report = Builder(BuilderConfig(B=2000, F0=1.0, hedge_layers=1)).build(
        corpus, store, "index/logs")
    print(f"index: L*={report.L} layers (+{report.L_total - report.L} hedge)"
          f", expected FP/query={report.expected_fp:.3f},"
          f" {report.index_bytes / 1024:.0f} KiB on cloud storage,"
          f" {report.n_common} common words")

    # 3. Searcher: boots from ONE header read, then queries in two
    #    parallel-fetch rounds (never a dependent chain)
    cloud = SimCloudStore(store, seed=42)
    searcher = Searcher(cloud, "index/logs")
    print(f"searcher init: {searcher.init_stats.elapsed_s * 1e3:.0f} ms "
          f"(one read)")

    for query in ("error", "terminating", "0x1125"):
        res = searcher.query(query)
        print(f"  '{query}': {res.stats.n_results} docs in "
              f"{res.stats.total_s * 1e3:.0f} ms "
              f"({res.stats.rounds} rounds, "
              f"{res.stats.n_false_positives} false positives filtered)")
        for text in res.texts[:2]:
            print(f"      {text[:100]}")

    # 4. Boolean + top-K queries (§IV-D, §IV-F)
    res = searcher.query(And((Term("error"), Term("fetch"))), top_k=3)
    print(f"  'error AND fetch' top-3: {len(res.texts)} docs in "
          f"{res.stats.total_s * 1e3:.0f} ms")

    # 5. hedged read (§IV-G): straggler-proof lookup
    res = searcher.query("block", hedge=True)
    print(f"  hedged 'block': {res.stats.n_results} docs, abandoned "
          f"{res.stats.lookup.n_hedged_abandoned} straggler request(s)")


if __name__ == "__main__":
    main()
