"""Quickstart: the index lifecycle on (simulated) cloud storage — build,
open, search, append a delta segment, merge. The paper's Figure 1 flow
end to end, through the `Index` façade (docs/index_lifecycle.md).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro import BuilderConfig, Index
from repro.data import make_logs_like, write_corpus
from repro.index import And, Not, Phrase, Regex, Term, parse, to_string
from repro.storage import InMemoryBlobStore, SimCloudStore


def main() -> None:
    # 1. put a corpus in "cloud storage" (log lines, Loghub-style)
    store = InMemoryBlobStore()
    docs = make_logs_like(5000, seed=1)
    corpus = write_corpus(store, "corpus/logs", docs, n_blobs=4)
    print(f"corpus: {corpus.n_docs} documents in 4 blobs")

    # 2. Index.build: profile -> optimize (Algorithm 1) -> compact ->
    #    persist base + manifest (generation 1)
    index = Index.build(corpus, BuilderConfig(B=8000, F0=1.0,
                                              hedge_layers=1,
                                              index_ngrams=3),
                        store, "index/logs")
    report = index.report
    print(f"index: generation {index.generation}, L*={report.L} layers "
          f"(+{report.L_total - report.L} hedge), expected "
          f"FP/query={report.expected_fp:.3f}, "
          f"{report.index_bytes / 1024:.0f} KiB on cloud storage, "
          f"{report.n_common} common words")

    # 3. Index.open anywhere: one LIST + one manifest read, then one
    #    header read per unit. Queries run in two parallel-fetch rounds
    #    (never a dependent chain).
    index = Index.open(SimCloudStore(store, seed=42), "index/logs")
    searcher = index.searcher()
    print(f"searcher init: {searcher.init_stats.elapsed_s * 1e3:.0f} ms "
          f"(header read)")

    for query in ("error", "terminating", "0x1125"):
        res = searcher.query(query)
        print(f"  '{query}': {res.stats.n_results} docs in "
              f"{res.stats.total_s * 1e3:.0f} ms "
              f"({res.stats.rounds} rounds, "
              f"{res.stats.n_false_positives} false positives filtered)")
        for text in res.texts[:2]:
            print(f"      {text[:100]}")

    # 4. Boolean + top-K queries (§IV-D, §IV-F)
    res = searcher.query(And((Term("error"), Term("fetch"))), top_k=3)
    print(f"  'error AND fetch' top-3: {len(res.texts)} docs in "
          f"{res.stats.total_s * 1e3:.0f} ms")

    # 4b. composable queries (docs/query_language.md): NOT, phrases, and
    #     regex compose freely; one planner lowers every tree to the same
    #     two-round pipeline, and results stay exact (content-verified)
    for q in (And((Term("info"), Not(Term("block")))),         # AND-NOT
              Phrase(("failed", "fetch")),                     # adjacency
              Phrase(("task", "stage"), slop=2),               # proximity
              And((Term("info"), Regex(r"blk_1[0-9]{3}\b"))),  # regex ∧ term
              parse('"failed fetch" OR terminating')):         # query text
        res = searcher.query(q)
        print(f"  {to_string(q)!r}: {res.stats.n_results} docs "
              f"({res.stats.n_candidates} candidates, "
              f"{res.stats.n_false_positives} FPs filtered)")

    # 5. hedged read (§IV-G): straggler-proof lookup
    res = searcher.query("block", hedge=True)
    print(f"  hedged 'block': {res.stats.n_results} docs, abandoned "
          f"{res.stats.lookup.n_hedged_abandoned} straggler request(s)")

    # 6. writer session: append a delta segment, commit a new generation
    fresh = make_logs_like(800, seed=9)
    delta = write_corpus(store, "corpus/logs-delta", fresh, n_blobs=2)
    writer = index.writer()
    writer.append(delta)
    writer.commit()
    searcher = index.searcher()       # base + 1 segment, shared rounds
    res = searcher.query("error")
    print(f"after commit: generation {index.generation}, "
          f"{index.n_segments} segment(s); 'error' now "
          f"{res.stats.n_results} docs")

    # 7. merge: compact base + segments back into one base index
    writer.merge()
    res = index.searcher().query("error")
    print(f"after merge: generation {index.generation}, "
          f"{index.n_segments} segments; 'error' still "
          f"{res.stats.n_results} docs")


if __name__ == "__main__":
    main()
