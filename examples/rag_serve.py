"""Retrieval-augmented serving: the Airphant searcher feeds document
context to an LM decoding with a KV cache — storage-side contribution
meeting the TPU-side substrate.

    PYTHONPATH=src python examples/rag_serve.py
"""

import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.data import make_logs_like, write_corpus
from repro.index import Builder, BuilderConfig
from repro.models import build_model, init_params
from repro.serving import RAGPipeline, SearchService
from repro.storage import InMemoryBlobStore, SimCloudStore


def main() -> None:
    store = InMemoryBlobStore()
    docs = make_logs_like(3000, seed=9)
    corpus = write_corpus(store, "corpus/logs", docs, n_blobs=4)
    Builder(BuilderConfig(B=1500, F0=1.0)).build(corpus, store, "index/r")

    cfg = get_config("qwen3-32b", reduced=True).with_(
        n_layers=4, d_model=256, n_heads=4, n_kv=2, d_ff=512,
        vocab=32_000, head_dim=64)
    model = build_model(cfg)
    params = init_params(model.param_desc(), jax.random.PRNGKey(0))

    svc = SearchService(SimCloudStore(store, seed=3), "index/r")
    rag = RAGPipeline(svc, model, params, vocab_size=cfg.vocab,
                      max_context=128)

    for query in ("error fetch", "block terminating"):
        out = rag.generate(query, top_k_docs=3, max_new_tokens=12)
        print(f"query   : {query}")
        print(f"retrieved {len(out.retrieved)} docs in "
              f"{out.retrieval_ms:.0f} ms (simulated cloud)")
        for doc in out.retrieved[:2]:
            print(f"   ctx: {doc[:90]}")
        print(f"decoded {out.n_decoded} tokens: {out.tokens.tolist()}\n")


if __name__ == "__main__":
    main()
