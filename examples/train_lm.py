"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on a keyword-filtered corpus streamed through the Airphant index,
with mid-run checkpointing + kill-and-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.data import make_logs_like, write_corpus
from repro.data.pipeline import IndexedCorpusLoader, PipelineConfig
from repro.index import Builder, BuilderConfig
from repro.models import NULL_RULES, build_model, init_params, param_count
from repro.storage import InMemoryBlobStore, SimCloudStore
from repro.training import CheckpointManager, OptimizerConfig
from repro.training.train_loop import TrainLoopConfig, run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--kill-at", type=int, default=None,
                    help="simulate a crash after this step, then resume")
    args = ap.parse_args()

    # ~35M params (CPU-friendly); scale n_layers/d_model up on accelerators
    cfg = get_config("granite-20b", reduced=True).with_(
        n_layers=6, d_model=384, n_heads=6, n_kv=2, d_ff=1152,
        vocab=32_000, attn_chunk=128)
    model = build_model(cfg)
    params = init_params(model.param_desc(), jax.random.PRNGKey(0))
    print(f"model: {param_count(params) / 1e6:.1f}M parameters")

    # corpus + index on cloud storage; train on docs containing 'block'
    store = InMemoryBlobStore()
    docs = make_logs_like(8000, seed=3)
    corpus = write_corpus(store, "corpus/logs", docs, n_blobs=4)
    Builder(BuilderConfig(B=2000, F0=1.0, hedge_layers=1)).build(
        corpus, store, "index/logs")
    loader = IndexedCorpusLoader(
        SimCloudStore(store, seed=0), "index/logs",
        PipelineConfig(seq_len=128, batch_size=4, vocab_size=cfg.vocab),
        query="block")
    print(f"pipeline: {len(loader._texts)} documents match 'block'")

    ckpt = CheckpointManager(store)
    opt_cfg = OptimizerConfig(lr=6e-4, warmup_steps=20,
                              total_steps=args.steps)

    def train(total_steps):
        loop = TrainLoopConfig(total_steps=total_steps, checkpoint_every=40,
                               log_every=20)
        t0 = time.time()
        state, log = run(model, params, loader, ckpt, loop, opt_cfg,
                         NULL_RULES)
        dt = time.time() - t0
        if log.resumed_from:
            print(f"resumed from checkpoint at step {log.resumed_from}")
        for s, l in zip(log.steps, log.losses):
            print(f"  step {s:4d}  loss {l:.4f}")
        tokens = 4 * 128 * (total_steps - (log.resumed_from or 0))
        print(f"{dt:.0f}s, {tokens / max(dt, 1e-9):.0f} tokens/s (CPU)")
        return state, log

    if args.kill_at:
        print(f"-- training to step {args.kill_at}, then 'crashing' --")
        train(args.kill_at)
        print("-- restarted process: auto-resume from latest checkpoint --")
    state, log = train(args.steps)
    assert log.losses[-1] < log.losses[0], "loss must decrease"
    print("final loss:", log.losses[-1])


if __name__ == "__main__":
    main()
