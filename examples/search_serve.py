"""Serve batched keyword queries against a cloud-stored index and compare
Airphant's latency profile with the baseline index structures — the
paper's §V experiments in miniature.

    PYTHONPATH=src python examples/search_serve.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.data import make_logs_like, write_corpus
from repro.data.tokenizer import distinct_words
from repro.index import Builder, BuilderConfig
from repro.index.baselines import BTreeIndex, SkipListIndex
from repro.serving import SearchService
from repro.storage import REGIONS, InMemoryBlobStore, SimCloudStore


def main() -> None:
    store = InMemoryBlobStore()
    docs = make_logs_like(6000, seed=5)
    corpus = write_corpus(store, "corpus/logs", docs, n_blobs=4)
    Builder(BuilderConfig(B=2000, F0=1.0, hedge_layers=1)).build(
        corpus, store, "index/air")
    BTreeIndex(store, "index/bt").build(corpus)
    SkipListIndex(store, "index/sl").build(corpus)

    truth = {}
    for i, d in enumerate(docs):
        for w in distinct_words(d):
            truth.setdefault(w, set()).add(i)
    rng = np.random.default_rng(0)
    queries = [str(w) for w in rng.choice(sorted(truth), 50, replace=False)]

    print("=== within-region (us-central1) ===")
    svc = SearchService(SimCloudStore(store, seed=1), "index/air",
                        hedge=True)
    svc.search_batch(queries, top_k=10)
    summary = svc.stats.summary()
    print(f"airphant : mean {summary['mean_ms']:.0f} ms   "
          f"p99 {summary['p99_ms']:.0f} ms   "
          f"wait {summary['wait_ms']:.0f} / download "
          f"{summary['download_ms']:.1f} ms   "
          f"avgFP {summary['avg_false_positives']:.2f}")

    for name, prefix, cls in (("btree", "index/bt", BTreeIndex),
                              ("skiplist", "index/sl", SkipListIndex)):
        searcher = cls(store, prefix).open(SimCloudStore(store, seed=1))
        lat = [searcher.query(q, top_k=10).stats.total_s for q in queries]
        print(f"{name:9s}: mean {np.mean(lat) * 1e3:.0f} ms   "
              f"p99 {np.percentile(lat, 99) * 1e3:.0f} ms")

    print("=== cross-region ===")
    for region, model in REGIONS.items():
        svc = SearchService(SimCloudStore(store, model=model, seed=2),
                            "index/air")
        svc.search_batch(queries[:20])
        print(f"airphant @ {region:16s}: "
              f"mean {svc.stats.summary()['mean_ms']:.0f} ms")


if __name__ == "__main__":
    main()
